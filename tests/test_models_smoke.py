"""Per-arch smoke tests: reduced configs of the same family run one
forward/train step on CPU with finite loss/grads and correct shapes, and
prefill+decode matches the full forward (exact for attention archs /
capacity-relaxed MoE; bf16-tolerance for recurrent state handoff)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import SHAPES, shape_applicable
from repro.configs.registry import ARCH_IDS, get_config, reduced_config
from repro.models import layers as ly
from repro.models import model as M
from repro.models import transformer as tf

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B, S, with_labels=True):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    if cfg.frontend != "none":
        b = {"embeds": jax.random.normal(KEY, (B, S, cfg.d_model),
                                         jnp.bfloat16)}
    else:
        b = {"tokens": tokens}
    if with_labels:
        b["labels"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = reduced_config(arch)
    params = M.init_params(KEY, cfg)
    loss, grads = jax.jit(M.make_train_step(cfg))(params, _batch(cfg, 2, 64))
    assert jnp.isfinite(loss), arch
    assert float(loss) > 0
    for g in jax.tree.leaves(grads):
        assert bool(jnp.isfinite(g).all()), arch
    # grads structurally match params
    assert jax.tree.structure(grads) == jax.tree.structure(params)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes(arch):
    cfg = reduced_config(arch)
    params = M.init_params(KEY, cfg)
    b = _batch(cfg, 2, 32, with_labels=False)
    x = tf.embed_inputs(params, b, cfg)
    y, aux, _ = tf.forward(params, x, cfg, mode="train")
    assert y.shape == (2, 32, cfg.d_model)
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ["yi-9b", "gemma-2b", "qwen2.5-32b",
                                  "deepseek-moe-16b", "internvl2-2b"])
def test_prefill_decode_consistency_exact(arch):
    # train/prefill use flash attention with bf16 probability tiles; decode
    # uses exact f32 softmax over the cache — agreement is bf16-precision
    # bounded (~1e-2 on logits), verified exact in f32 during development.
    cfg = reduced_config(arch)
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    _decode_consistency(cfg, tol=0.03)


@pytest.mark.parametrize("arch", ["xlstm-1.3b", "zamba2-2.7b"])
def test_prefill_decode_consistency_recurrent(arch):
    # bf16 parallel-vs-recurrent state handoff: precision-limited
    _decode_consistency(reduced_config(arch), tol=0.06)


def _decode_consistency(cfg, tol):
    params = M.init_params(KEY, cfg)
    B, S = 2, 32
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    b = ({"embeds": jnp.take(params["embed"], tokens, axis=0)}
         if cfg.frontend != "none" else {"tokens": tokens})
    last_logits, cache = jax.jit(lambda p, bb: tf.prefill(p, bb, cfg)
                                 )(params, b)
    nxt = jnp.argmax(last_logits[:, :cfg.vocab_size], axis=-1
                     )[:, None].astype(jnp.int32)
    toks2 = jnp.concatenate([tokens, nxt], axis=1)
    b2 = ({"embeds": jnp.take(params["embed"], toks2, axis=0)}
          if cfg.frontend != "none" else {"tokens": toks2})
    y2, _, _ = jax.jit(lambda p, bb: tf.forward(
        p, tf.embed_inputs(p, bb, cfg), cfg, mode="train"))(params, b2)
    ref_logits = ly.logits_fn(params, y2[:, -1:], cfg)[:, 0]

    def pad_cache(c):
        c = dict(c)
        for k in ("kv", "shared_kv"):
            if k in c:
                c[k] = {kk: jnp.pad(v, ((0, 0), (0, 0), (0, 8), (0, 0),
                                        (0, 0))) for kk, v in c[k].items()}
        return c
    cache = pad_cache(cache)
    dec_logits, _ = jax.jit(lambda p, c, t, i: tf.decode_step(p, c, t, i, cfg)
                            )(params, cache, nxt, jnp.int32(S))
    scale = float(jnp.max(jnp.abs(ref_logits))) + 1e-6
    err = float(jnp.max(jnp.abs(dec_logits - ref_logits))) / scale
    assert err < tol, err


def test_exact_configs_match_assignment():
    """The full configs carry the assigned hyperparameters exactly."""
    spec = {
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "yi-9b": (48, 4096, 32, 4, 11008, 64000),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
    }
    for arch, (L, d, H, K, ff, V) in spec.items():
        cfg = get_config(arch)
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, H, K, ff, V), arch
    moe = get_config("granite-moe-3b-a800m").moe
    assert (moe.num_experts, moe.top_k) == (40, 8)
    moe = get_config("deepseek-moe-16b").moe
    assert (moe.num_experts, moe.top_k, moe.num_shared_experts) == (64, 6, 2)
    assert get_config("zamba2-2.7b").ssm.d_state == 64
    assert get_config("gemma-2b").resolved_head_dim == 256
    assert get_config("qwen2.5-32b").qkv_bias


def test_input_specs_cover_all_cells():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            if not shape_applicable(cfg, shape):
                assert shape.name == "long_500k"
                continue
            specs = M.input_specs(cfg, shape)
            if shape.kind == "train":
                lb = specs["batch"]["labels"]
                assert lb.shape == (shape.global_batch, shape.seq_len)
            elif shape.kind == "decode":
                assert specs["tokens"].shape == (shape.global_batch, 1)
                assert "cache" in specs
