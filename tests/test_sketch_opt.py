"""Sketched optimizer-state subsystem (repro.sketch + kernels/sketch_update):
CSVec statistics, fused kernel vs oracle, sketched AdamW tracking dense,
checkpoint roundtrip, sharding specs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import reduced_config
from repro.kernels.ops import sketch_update_op
from repro.kernels.ref import sketch_update_ref
from repro.kernels.sketch_update import sketch_update
from repro.models import model as M
from repro.sketch import csvec as cv
from repro.sketch.hashing import cached_coeffs, row_buckets_signs
from repro.sketch.optimizer import (SketchedMoments, moment_state_bytes,
                                    sketched_adagrad_init,
                                    sketched_adagrad_update,
                                    sketched_adamw_init,
                                    sketched_adamw_update)
from repro.train import checkpoint as ckpt
from repro.train.data import make_batch
from repro.train.optimizer import adamw_init, adamw_update, make_optimizer


# ---------------------------------------------------------------------------
# Hashing
# ---------------------------------------------------------------------------


def test_hash_uniformity_and_signs():
    bk, sg = row_buckets_signs(cached_coeffs(3, 4), jnp.arange(100_000),
                               256, True)
    assert int(bk.min()) >= 0 and int(bk.max()) < 256
    cnt = np.bincount(np.asarray(bk[0]), minlength=256)
    assert cnt.std() / cnt.mean() < 0.1          # near-uniform buckets
    assert abs(float(sg.mean())) < 0.02          # balanced signs
    assert set(np.unique(np.asarray(sg))) == {-1.0, 1.0}
    # rows differ (independent coefficients)
    assert np.mean(np.asarray(bk[0]) == np.asarray(bk[1])) < 0.02


# ---------------------------------------------------------------------------
# CSVec container
# ---------------------------------------------------------------------------


def _planted_vec(d=4096, key=0):
    vec = jax.random.normal(jax.random.PRNGKey(key), (d,))
    return vec.at[jnp.array([7, 99, 1234])].set(jnp.array([50., -40., 30.]))


def test_csvec_roundtrip_unbiased():
    """Mean of query over independent hash seeds converges to the vector."""
    vec = _planted_vec()
    ests = [cv.query_all(cv.accumulate(cv.csvec_zeros(4096, 512, 3, seed=s),
                                       vec))
            for s in range(20)]
    one = float(jnp.linalg.norm(ests[0] - vec) / jnp.linalg.norm(vec))
    mean = jnp.mean(jnp.stack(ests), axis=0)
    avg = float(jnp.linalg.norm(mean - vec) / jnp.linalg.norm(vec))
    assert avg < 0.5 * one, (avg, one)   # error shrinks ~ 1/sqrt(n_seeds)


def test_csvec_median_beats_single_row():
    vec = _planted_vec()
    sk = cv.accumulate(cv.csvec_zeros(4096, 512, 5, seed=11), vec)
    idx = jnp.arange(4096)
    med_err = float(jnp.linalg.norm(cv.query(sk, idx) - vec))
    row_errs = [float(jnp.linalg.norm(cv.query_row(sk, idx, r) - vec))
                for r in range(5)]
    assert med_err < min(row_errs), (med_err, row_errs)


def test_csvec_topk_recovers_heavy_hitters():
    vec = _planted_vec()
    sk = cv.accumulate(cv.csvec_zeros(4096, 512, 3, seed=5), vec)
    ix, vals = cv.topk(sk, 3)
    assert sorted(np.asarray(ix).tolist()) == [7, 99, 1234]
    np.testing.assert_allclose(np.asarray(vals),
                               [50., -40., 30.], atol=3.0)


def test_csvec_countmin_overestimates():
    """Unsigned min-of-rows never underestimates a nonnegative stream —
    the safety property the sketched v relies on."""
    vec = jnp.square(_planted_vec(key=3))
    sk = cv.accumulate(cv.csvec_zeros(4096, 512, 3, seed=9, signed=False),
                       vec)
    est = cv.query_all(sk)
    assert bool(jnp.all(est >= vec - 1e-4))


def test_csvec_merge_linear():
    a = jax.random.normal(jax.random.PRNGKey(1), (1024,))
    b = jax.random.normal(jax.random.PRNGKey(2), (1024,))
    z = cv.csvec_zeros(1024, 256, 3, seed=4)
    merged = cv.merge(cv.accumulate(z, a), cv.accumulate(z, b))
    direct = cv.accumulate(z, a + b)
    np.testing.assert_allclose(np.asarray(merged.table),
                               np.asarray(direct.table), rtol=1e-5,
                               atol=1e-5)
    # different hash seeds must be rejected, not silently summed
    with pytest.raises(ValueError):
        cv.merge(cv.accumulate(z, a),
                 cv.accumulate(cv.csvec_zeros(1024, 256, 3, seed=5), b))


# ---------------------------------------------------------------------------
# Fused kernel vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(1000, 300, 3), (4096, 512, 3),
                                   (700, 128, 4), (8192, 640, 2)])
def test_sketch_update_kernel_matches_ref(shape):
    n, C, R = shape
    g = jax.random.normal(jax.random.PRNGKey(n), (n,))
    mt = jax.random.normal(jax.random.PRNGKey(n + 1), (R, C))
    vt = jnp.abs(jax.random.normal(jax.random.PRNGKey(n + 2), (R, C)))
    cm, cvv = cached_coeffs(n + 3, R), cached_coeffs(n + 4, R)
    ref_out = sketch_update_ref(g, mt, vt, cm, cvv, 0.9, 0.95)
    pal_out = sketch_update(g, mt, vt, cm, cvv, b1=0.9, b2=0.95,
                            bI=256, bC=128, interpret=True)
    for name, a, b in zip(("new_m", "new_v", "m_hat", "v_hat"),
                          ref_out, pal_out):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5, err_msg=name)


def test_sketch_update_op_dispatch():
    g = jax.random.normal(jax.random.PRNGKey(0), (2048,))
    mt = jnp.zeros((3, 256))
    vt = jnp.zeros((3, 256))
    cm, cvv = cached_coeffs(1, 3), cached_coeffs(2, 3)
    a = sketch_update_op(g, mt, vt, cm, cvv, b1=0.9, b2=0.95,
                         use_pallas=True)
    b = sketch_update_op(g, mt, vt, cm, cvv, b1=0.9, b2=0.95,
                         use_pallas=False)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Sketched optimizers
# ---------------------------------------------------------------------------


def test_sketched_adagrad_minimizes_quadratic():
    d = 1 << 14
    target = jax.random.normal(jax.random.PRNGKey(1), (d,))
    w = {"w": jnp.zeros((d,))}
    st = sketched_adagrad_init(w, ratio=4, rows=3, min_elems=1024)
    for _ in range(200):
        g = jax.tree.map(lambda x: x - target, w)
        w, st = sketched_adagrad_update(g, st, w, lr=0.5)
    rel = float(jnp.linalg.norm(w["w"] - target) / jnp.linalg.norm(target))
    assert rel < 0.05, rel


def test_sketched_adamw_tracks_dense_on_tiny_model():
    """Acceptance: ratio-4 sketched AdamW reaches final loss within 10% of
    dense AdamW in the same step budget, with >= 3x smaller (m, v) state
    for the compressed leaves."""
    cfg = reduced_config("yi-9b")
    base_step = M.make_train_step(cfg)
    steps, lr = 120, 1e-2

    def run(sketched):
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        if sketched:
            opt = sketched_adamw_init(params, ratio=4, rows=3,
                                      min_elems=4096)
            upd = lambda g, o, p: sketched_adamw_update(g, o, p, lr=lr)
        else:
            opt = adamw_init(params)
            upd = lambda g, o, p: adamw_update(g, o, p, lr=lr)

        @jax.jit
        def step_fn(params, opt, bd):
            loss, grads = base_step(params, bd)
            p2, o2 = upd(grads, opt, params)
            return loss, p2, o2

        loss = None
        for s in range(steps):
            bd = make_batch(cfg, s, 8, 64, 0)
            loss, params, opt = step_fn(params, opt, bd)
        return float(loss), opt

    dense_loss, _ = run(False)
    sk_loss, sk_opt = run(True)
    assert sk_loss <= 1.10 * dense_loss, (sk_loss, dense_loss)
    b = moment_state_bytes(sk_opt)
    assert b["sketched"] > 0
    assert b["sketched_dense_equiv"] / b["sketched"] >= 3.0, b


def test_make_optimizer_dispatch_and_loop():
    """cfg knob routes the train loop through the sketched optimizer."""
    from repro.train.loop import train
    cfg = reduced_config("gemma-2b")
    cfg = dataclasses.replace(cfg, sketch=dataclasses.replace(
        cfg.sketch, opt_state_ratio=4, opt_state_min_elems=4096))
    init, _ = make_optimizer(cfg, lr=1e-3)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    st = init(params)
    assert any(isinstance(mo, SketchedMoments) for mo in jax.tree.leaves(
        st.moments, is_leaf=lambda x: isinstance(x, tuple)
        and hasattr(x, "m")))
    h = train(cfg, steps=3, batch=2, seq=32, lr=1e-3, log_every=1000,
              log_fn=lambda *_: None)
    assert len(h.losses) == 3 and np.isfinite(h.losses).all()


def test_checkpoint_roundtrip_sketch_state(tmp_path):
    cfg = reduced_config("gemma-2b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    st = sketched_adamw_init(params, ratio=4, min_elems=4096)
    # a non-trivial state: apply one update
    g = jax.tree.map(jnp.ones_like, params)
    _, st = sketched_adamw_update(g, st, params, lr=1e-3)
    state = {"params": params, "opt": st}
    ckpt.save(str(tmp_path), 7, state)
    step, restored = ckpt.restore(str(tmp_path), state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_opt_state_pspecs_divide_evenly():
    from repro.configs.registry import get_config
    from repro.launch.shardings import (build_param_pspecs, make_rules,
                                        opt_state_pspecs)
    sizes = {"data": 16, "model": 16}
    cfg = get_config("gemma-2b")
    pshapes = M.param_specs(cfg)
    rules, strategy = make_rules(cfg, "train", False, False)
    specs = build_param_pspecs(cfg, pshapes, rules, strategy)
    st = sketched_adamw_init(pshapes, ratio=4)
    ospecs = opt_state_pspecs(cfg, st, specs)
    is_mom = lambda x: isinstance(x, tuple) and hasattr(x, "m")
    mleaves = jax.tree.leaves(st.moments, is_leaf=is_mom)
    sleaves = jax.tree.leaves(ospecs.moments, is_leaf=is_mom)
    n_sketched = 0
    for mo, sp in zip(mleaves, sleaves):
        if not isinstance(mo, SketchedMoments):
            continue
        n_sketched += 1
        for vec, spec in ((mo.m, sp.m), (mo.v, sp.v)):
            entry = tuple(spec.table)[1]
            n = 1
            for ax in (entry if isinstance(entry, tuple)
                       else (entry,) if entry else ()):
                n *= sizes[ax]
            assert vec.table.shape[1] % n == 0
            assert n >= 16          # tables actually shard on the mesh
    assert n_sketched > 0
