"""Serve-path observability: Chrome-trace schema + span nesting,
windowed metrics reconciling with cumulative EngineStats, log-bucket
histogram quantiles vs a numpy oracle, kind-tagged stats merge, the
sketch-fidelity probe, and the zero-interference contract (tracing
on/off bitwise-identical tokens, one decode compilation)."""
import asyncio
import dataclasses
import json
import math

import jax
import numpy as np
import pytest

from repro.configs.registry import reduced_config
from repro.models import model as M
from repro.obs import (Histogram, MetricsRegistry, ServeObserver, Tracer,
                       prometheus_text, write_trace)
from repro.serve import kv_sketch as kvs
from repro.serve.frontend import AsyncServeEngine
from repro.serve.scheduler import EngineStats, Request, SlotScheduler
from repro.serve.speculative import round_accounting


@pytest.fixture(scope="module")
def gemma():
    cfg = reduced_config("gemma-2b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _serve(cfg, **kw):
    base = dict(max_batch=2, max_seq=128, decode_chunk=4,
                prefill_bucket=16)
    base.update(kw)
    return dataclasses.replace(cfg.serve, **base)


def _prompts(cfg, lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size, (n,)).astype(np.int32)
            for n in lens]


# ---------------------------------------------------------------------------
# metrics.py: histogram + windowed registry
# ---------------------------------------------------------------------------


def test_histogram_quantiles_match_numpy_oracle():
    """Log-bucket quantiles land within one bucket (a factor of
    ``growth``) of the exact numpy quantile over a lognormal sample —
    the bound the geometric bucket interpolation guarantees."""
    rng = np.random.RandomState(0)
    xs = np.exp(rng.randn(5000) * 1.5 - 3.0)     # spans many buckets
    h = Histogram()
    for x in xs:
        h.observe(float(x))
    assert h.count == len(xs)
    assert h.sum == pytest.approx(xs.sum(), rel=1e-6)
    for q in (0.5, 0.9, 0.99):
        got = h.quantile(q)
        ref = float(np.quantile(xs, q))
        assert ref / h.growth <= got <= ref * h.growth, (q, got, ref)
    assert h.quantile(1.0) >= h.quantile(0.5)


def test_histogram_edge_cases():
    h = Histogram()
    assert h.quantile(0.5) == 0.0                # empty
    h.observe(0.0)                               # below lo: first bucket
    h.observe(1e9)                               # above hi: overflow
    assert h.count == 2
    assert h.quantile(0.99) >= h.hi              # overflow dominates tail


def test_window_counter_deltas_sum_to_totals():
    """Interval windows reconcile: per-window counter deltas sum to the
    cumulative total, rates are delta/duration, histogram window counts
    sum to the cumulative observation count."""
    reg = MetricsRegistry()
    deltas, hcounts = [], []
    for i in range(5):
        reg.counter("c").inc(i + 1)
        reg.counter("c").inc(0.5)
        for _ in range(i):
            reg.hist("h").observe(0.01 * (i + 1))
        w = reg.window()
        deltas.append(w["counters"]["c"]["delta"])
        hcounts.append(w["hists"]["h"]["count"] if "h" in w["hists"]
                       else 0)
    assert sum(deltas) == pytest.approx(reg.counter("c").value)
    assert sum(hcounts) == reg.hist("h").count
    assert w["counters"]["c"]["total"] == pytest.approx(
        reg.counter("c").value)
    assert w["seq"] == 5


def test_update_from_stats_and_prometheus_text():
    reg = MetricsRegistry()
    st = EngineStats(completed=7, blocks_peak=3, queue_depth=2)
    reg.update_from_stats(st)
    w = reg.window()
    assert w["counters"]["engine.completed"]["total"] == 7.0
    assert w["counters"]["engine.completed"]["delta"] == 7.0
    assert w["gauges"]["engine.blocks_peak"] == 3.0    # peak -> gauge
    assert w["gauges"]["engine.queue_depth"] == 2.0
    reg.hist("lat").observe(0.25)
    text = prometheus_text(reg)
    assert "# TYPE repro_engine_completed counter" in text
    assert "repro_engine_completed 7" in text
    assert "# TYPE repro_engine_queue_depth gauge" in text
    assert 'repro_lat{quantile="0.5"}' in text
    assert "repro_lat_count 1" in text


# ---------------------------------------------------------------------------
# EngineStats merge kinds (satellite: counter / gauge / peak semantics)
# ---------------------------------------------------------------------------


def test_engine_stats_merge_kinds():
    a = EngineStats(completed=3, blocks_peak=10, kv_peak_used_bytes=100,
                    queue_depth=2, block_size=16, fold_rows=5)
    b = EngineStats(completed=4, blocks_peak=7, kv_peak_used_bytes=300,
                    queue_depth=1, block_size=16, fold_rows=0)
    m = EngineStats.merge([a, b])
    assert m.completed == 7                      # counter: sum
    assert m.fold_rows == 5
    assert m.blocks_peak == 10                   # peak: max, NOT sum
    assert m.kv_peak_used_bytes == 300
    assert m.queue_depth == 3                    # disjoint-queue gauge sum
    assert m.block_size == 16                    # geometry: max, not 32
    kinds = EngineStats.field_kinds()
    assert kinds["completed"] == "counter"
    assert kinds["blocks_peak"] == "peak"
    assert kinds["queue_depth"] == "gauge"
    assert EngineStats.merge([]) == EngineStats()


def test_spec_round_accounting():
    assert round_accounting(0, 3) == (0, 0, 0)
    assert round_accounting(4, 0) == (0, 0, 0)
    # one verify round: K proposed, emitted-1 accepted (the +1 is the
    # verifier's own token, emitted even on zero acceptance)
    assert round_accounting(4, 1) == (1, 4, 0)
    assert round_accounting(4, 5) == (1, 4, 4)


# ---------------------------------------------------------------------------
# trace.py: schema + nesting over a real streamed workload
# ---------------------------------------------------------------------------


def _stream_workload(cfg, params, serve, obs, cancel_rid=None):
    """Submit a small stream through the async front-end; optionally
    hang up on one rid after its first delivered chunk."""
    sched = SlotScheduler(cfg, params, serve=serve, obs=obs)
    front = AsyncServeEngine(scheduler=sched)
    prompts = _prompts(cfg, [6, 11, 17, 9])

    async def go():
        handles = [await front.submit(p, max_new=10, rid=i)
                   for i, p in enumerate(prompts)]
        outs = {}

        async def consume(h):
            toks = []
            async for t in h.stream():
                toks.append(t)
                if h.rid == cancel_rid and len(toks) >= 2:
                    h.cancel()
            outs[h.rid] = toks
        await asyncio.gather(*[consume(h) for h in handles])
        return outs, {h.rid: h.completion for h in handles}

    outs, comps = asyncio.run(go())
    return sched, outs, comps


def test_trace_valid_chrome_json_with_nested_spans(gemma, tmp_path):
    """The exported trace is schema-valid Chrome trace-event JSON:
    every event carries ph/name/pid/ts, async b/e pairs balance per
    (cat, id, name), and each request's "active" (residency) span nests
    inside its enclosing req span.  Covers ok + cancelled requests."""
    cfg, params = gemma
    obs = ServeObserver(tracer=Tracer(sample_rate=1.0))
    sched, _, comps = _stream_workload(cfg, params, _serve(cfg), obs,
                                       cancel_rid=2)
    assert comps[2].status == "cancelled"
    assert all(c.status == "ok" for r, c in comps.items() if r != 2)

    path = tmp_path / "trace.json"
    n = write_trace(obs.tracer, str(path))
    doc = json.loads(path.read_text())
    ev = doc["traceEvents"]
    assert len(ev) == n > 0
    for e in ev:
        assert e["ph"] in ("b", "e", "X", "i", "C")
        assert isinstance(e["name"], str) and e["name"]
        assert isinstance(e["ts"], (int, float))
        assert e["pid"] == 1
        if e["ph"] in ("b", "e"):
            assert e["cat"] == "request" and "id" in e
        if e["ph"] == "X":
            assert e["dur"] >= 0

    # async spans balance, and "active" nests inside req{rid}
    for rid in comps:
        spans = [e for e in ev
                 if e["ph"] in ("b", "e") and e["id"] == rid]
        for name in (f"req{rid}", "active"):
            named = [e for e in spans if e["name"] == name]
            bs = [e for e in named if e["ph"] == "b"]
            es = [e for e in named if e["ph"] == "e"]
            assert len(bs) == len(es) >= 1, (rid, name)
        req_b = min(e["ts"] for e in spans if e["name"] == f"req{rid}"
                    and e["ph"] == "b")
        req_e = max(e["ts"] for e in spans if e["name"] == f"req{rid}"
                    and e["ph"] == "e")
        for e in spans:
            if e["name"] == "active":
                assert req_b <= e["ts"] <= req_e, (rid, e)

    # pump phases present as complete spans on the pump track
    assert any(e["ph"] == "X" and e["name"] == "dispatch" for e in ev)
    assert any(e["ph"] == "X" and e["name"] == "collect" for e in ev)
    assert any(e["ph"] == "C" and e["name"] == "engine" for e in ev)


def test_trace_sampling_deterministic():
    tr = Tracer(sample_rate=0.5)
    picks = [tr.sampled(rid) for rid in range(200)]
    assert picks == [tr.sampled(rid) for rid in range(200)]
    assert 20 < sum(picks) < 180          # hash spreads, not all-or-none
    assert Tracer(sample_rate=1.0).sampled(7)
    assert not Tracer(sample_rate=0.0).sampled(7)


def test_tracer_bounded_drops_counted():
    tr = Tracer(sample_rate=1.0, max_events=10)
    for i in range(50):
        tr.instant(f"e{i}")
    ev = tr.events()
    assert len(ev) == 11                  # cap + one metadata instant
    assert ev[-1]["name"] == "tracer_dropped_events"
    assert ev[-1]["args"]["dropped"] == 40


# ---------------------------------------------------------------------------
# zero-interference: tracing on/off bitwise, one compile
# ---------------------------------------------------------------------------


def test_tracing_onoff_bitwise_identical_one_compile(gemma, tmp_path):
    """Full observability (tracing + per-round metrics flush) changes
    NOTHING about the served tokens and adds no compilation: the
    observer is host-side bookkeeping only."""
    cfg, params = gemma
    serve = _serve(cfg)
    s_off, out_off, _ = _stream_workload(cfg, params, serve, None)
    obs = ServeObserver(tracer=Tracer(sample_rate=1.0),
                        metrics_path=str(tmp_path / "m.jsonl"),
                        metrics_interval=0.0)
    s_on, out_on, _ = _stream_workload(cfg, params, serve, obs)
    assert out_on == out_off
    assert s_off.decode_compilations == 1
    assert s_on.decode_compilations == 1


# ---------------------------------------------------------------------------
# windowed engine counters reconcile with cumulative EngineStats
# ---------------------------------------------------------------------------


def test_windowed_engine_counters_sum_to_engine_stats(gemma, tmp_path):
    """With a flush every decode round, the per-window deltas of every
    counter-kind ``engine.*`` series sum back to the final cumulative
    EngineStats value — windows partition the counters exactly.  The
    JSONL sink holds the same windows the observer retained."""
    cfg, params = gemma
    path = tmp_path / "metrics.jsonl"
    obs = ServeObserver(metrics_path=str(path), metrics_interval=0.0)
    sched, _, comps = _stream_workload(cfg, params, _serve(cfg), obs,
                                       cancel_rid=1)
    final = sched.stats()
    obs.close(stats=final)

    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert lines and len(lines) == len(obs.windows)
    kinds = EngineStats.field_kinds()
    for f, kind in kinds.items():
        if kind != "counter":
            continue
        name = f"engine.{f}"
        total = sum(w["counters"].get(name, {"delta": 0.0})["delta"]
                    for w in lines)
        assert total == pytest.approx(float(getattr(final, f))), (
            name, total, getattr(final, f))
    # the serve-layer token counter reconciles against completions too
    served = sum(len(c.tokens) for c in comps.values())
    got = sum(w["counters"]["serve.tokens_delivered"]["delta"]
              for w in lines if "serve.tokens_delivered" in w["counters"])
    assert got == served
    st = [w["counters"]["serve.completions.cancelled"]["total"]
          for w in lines if "serve.completions.cancelled" in w["counters"]]
    assert st and st[-1] == 1.0
    # latency series came through the windows
    assert any("serve.ttft_s" in w["hists"] for w in lines)


# ---------------------------------------------------------------------------
# sketch-fidelity probe
# ---------------------------------------------------------------------------


def test_tail_row_spread_math():
    """Empty tail -> exactly 0 (guarded median); folded rows -> finite,
    non-negative, and only for slots that actually folded."""
    tail = {"k": np.zeros((2, 3, 3, 8, 1, 4), np.float32),
            "v": np.zeros((2, 3, 3, 8, 1, 4), np.float32)}
    sp = np.asarray(kvs.tail_row_spread(
        {k: jax.numpy.asarray(v) for k, v in tail.items()}))
    assert sp.shape == (3,)
    np.testing.assert_array_equal(sp, 0.0)

    rng = np.random.RandomState(0)
    tail["k"][:, 1] = rng.randn(2, 3, 8, 1, 4)
    tail["v"][:, 1] = rng.randn(2, 3, 8, 1, 4)
    sp = np.asarray(kvs.tail_row_spread(
        {k: jax.numpy.asarray(v) for k, v in tail.items()}))
    assert sp[0] == 0.0 and sp[2] == 0.0
    assert np.isfinite(sp[1]) and sp[1] >= 0.0


def test_fidelity_probe_emits_gauge_for_folded_slot(gemma):
    """A long-context sketched request (context >> window) with
    ``fidelity_every=1`` produces a tail-spread gauge + histogram series
    for its folded slot — computed at collect() boundaries only, with
    the engine still compiling decode once."""
    cfg, params = gemma
    bs = cfg.serve.kv_block_size
    serve = _serve(cfg, max_batch=1, max_seq=256, num_kv_blocks=24,
                   kv_sketch_window=2 * bs)
    obs = ServeObserver(metrics_interval=0.0, fidelity_every=1)
    sched = SlotScheduler(cfg, params, serve=serve, obs=obs)
    p = _prompts(cfg, [150])[0]
    done = sched.run([Request(rid=0, tokens=p, max_new=6)])
    assert done[0].status == "ok"
    assert sched.decode_compilations == 1
    assert sched.fold_rows_total > 0
    w = obs.flush()
    assert "kv.tail_spread.slot0" in w["gauges"]
    spread = w["gauges"]["kv.tail_spread.slot0"]
    assert math.isfinite(spread) and spread >= 0.0
    assert w["hists"].get("kv.tail_spread", {"count": 0})["count"] >= 0
    assert obs.registry.hist("kv.tail_spread").count >= 1


def test_fidelity_probe_off_by_default(gemma):
    cfg, params = gemma
    bs = cfg.serve.kv_block_size
    serve = _serve(cfg, max_batch=1, max_seq=256, num_kv_blocks=24,
                   kv_sketch_window=2 * bs)
    obs = ServeObserver(metrics_interval=0.0)      # fidelity_every=0
    sched = SlotScheduler(cfg, params, serve=serve, obs=obs)
    sched.run([Request(rid=0, tokens=_prompts(cfg, [150])[0], max_new=4)])
    w = obs.flush()
    assert not any(k.startswith("kv.tail_spread") for k in w["gauges"])
