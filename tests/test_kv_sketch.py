"""Sketched long-context KV subsystem (serve/kv_sketch.py): bitwise
short-context regression, fold-then-query fidelity across compression
ratios, fold-through long-context decode past the pool's row capacity,
slot lifecycle with live tails, speculative identity, pspecs coverage,
Pallas kernels vs oracles, and the freed-block prefix-cache guard."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import reduced_config
from repro.models import model as M
from repro.serve import kv_sketch as kvs
from repro.serve.scheduler import Request, SlotScheduler


@pytest.fixture(scope="module")
def gemma():
    cfg = reduced_config("gemma-2b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _serve(cfg, **kw):
    base = dict(max_batch=2, max_seq=128, decode_chunk=4,
                prefill_bucket=16)
    base.update(kw)
    return dataclasses.replace(cfg.serve, **base)


def _prompts(cfg, lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size, (n,)).astype(np.int32)
            for n in lens]


def _run(cfg, params, serve, reqs):
    sched = SlotScheduler(cfg, params, serve=serve)
    return sched, {c.rid: list(c.tokens) for c in sched.run(reqs)}


# ---------------------------------------------------------------------------
# Bitwise regression + engine contracts
# ---------------------------------------------------------------------------


def test_short_context_bitwise_regression(gemma):
    """The regression anchor: a sketch engine whose window covers every
    context decodes BITWISE identically to a sketch-free engine (the
    two-span select picks the unchanged exact-path output), while decode
    and prefill still compile exactly once."""
    cfg, params = gemma
    lens = [5, 21, 13, 30]
    reqs = lambda: [Request(rid=i, tokens=p, max_new=4)
                    for i, p in enumerate(_prompts(cfg, lens))]
    _, ref = _run(cfg, params, _serve(cfg), reqs())
    s, got = _run(cfg, params,
                  _serve(cfg, kv_sketch_window=128), reqs())
    assert got == ref
    assert s.decode_compilations == 1
    assert s.prefill_compilations == 1


def test_opt_out_request_stays_exact(gemma):
    """Per-request kv_sketch=False keeps that request's whole context
    exact even on an engine with a small window — its tokens match a
    sketch-free engine's bitwise."""
    cfg, params = gemma
    (p,) = _prompts(cfg, [60], seed=3)
    bs = cfg.serve.kv_block_size
    sv = _serve(cfg, max_batch=1, kv_sketch_window=2 * bs)
    _, got = _run(cfg, params, sv,
                  [Request(rid=0, tokens=p, max_new=4, kv_sketch=False)])
    _, ref = _run(cfg, params, _serve(cfg, max_batch=1),
                  [Request(rid=0, tokens=p, max_new=4)])
    assert got == ref


def test_long_context_past_pool_capacity(gemma):
    """The tentpole claim: a slot decodes a context >= 4x the pool's row
    capacity — impossible for the exact paged path, which must reserve
    every block of the context — because aged blocks fold into the tail
    and return to the pool."""
    cfg, params = gemma
    bs = cfg.serve.kv_block_size
    nb = 9
    sv = _serve(cfg, max_batch=1, max_seq=1024, num_kv_blocks=nb,
                kv_sketch_window=4 * bs, admit_threshold=1 << 30)
    S = 4 * nb * bs + 20
    (p,) = _prompts(cfg, [S], seed=7)
    sched, done = _run(cfg, params, sv,
                       [Request(rid=0, tokens=p, max_new=6)])
    assert len(done[0]) == 6
    assert sched.decode_compilations == 1
    assert sched.prefill_compilations == 1
    assert sched.kv_sketch_tail_bytes() > 0
    # everything returned to the pool after retirement
    assert sched.alloc.reserved_bytes() == 0


def test_fold_fidelity_improves_with_ratio_and_rows(gemma):
    """Fold-then-query accuracy: the tail span's softmax output tracks
    the dense oracle better as compression relaxes (smaller ratio ->
    more cols) and as hash rows are added — the count-sketch variance
    contract, measured end-to-end through fold_rows + tail_attend."""
    cfg, params = gemma
    rng = np.random.RandomState(0)
    K, hd, R = 2, 16, 2
    T, bsz = 96, 16
    kr = jnp.asarray(rng.randn(1, T, K, hd).astype(np.float32))
    vr = jnp.asarray(rng.randn(1, T, K, hd).astype(np.float32))
    q = jnp.asarray(rng.randn(1, 1, K, R, hd).astype(np.float32))
    fb = jnp.asarray([T], jnp.int32)
    scale = 1.0 / float(np.sqrt(hd))
    _, l_o, acc_o = kvs.dense_tail_stats(q, kr, vr, fb, scale)
    oracle = (acc_o / l_o[..., None]).reshape(-1)

    def cos(ratio, rows):
        sv = dataclasses.replace(cfg.serve, kv_sketch_ratio=ratio,
                                 kv_sketch_rows=rows)
        coeffs = kvs.tail_coeffs(sv)
        C = kvs.tail_cols(T, ratio)
        onehot = kvs.pos_onehot(coeffs, kvs.pos_domain(T, bsz), C)
        tail = kvs.fold_rows(kr, vr, jnp.arange(T, dtype=jnp.int32),
                             coeffs, C)
        _, l_t, acc_t = kvs.tail_attend(q, tail["k"], tail["v"], onehot,
                                        fb, scale)
        out = (acc_t / jnp.maximum(l_t, 1e-30)[..., None]).reshape(-1)
        return float(jnp.vdot(out, oracle)
                     / (jnp.linalg.norm(out) * jnp.linalg.norm(oracle)))

    by_ratio = [cos(r, 3) for r in (8, 4, 1)]
    assert by_ratio == sorted(by_ratio), by_ratio
    assert by_ratio[-1] > 0.7, by_ratio
    assert cos(2, 5) > cos(2, 1)


def test_fold_pool_matches_fold_rows(gemma):
    """The in-chunk pool fold (block tables, traced lengths) and the
    reference explicit-row fold accumulate bitwise-identical tables for
    the same rows — they share row_buckets_signs."""
    cfg, params = gemma
    rng = np.random.RandomState(1)
    L, NB, bs, K, hd = 2, 6, 8, 2, 16
    Z, C = 3, 32
    sv = dataclasses.replace(cfg.serve, kv_sketch_rows=Z)
    coeffs = kvs.tail_coeffs(sv)
    pool = {"k": jnp.asarray(rng.randn(L, NB, bs, K, hd).astype(np.float32)),
            "v": jnp.asarray(rng.randn(L, NB, bs, K, hd).astype(np.float32))}
    # slot 0 holds physical blocks [4, 1, 2]; fold its first 2 blocks
    tables = jnp.asarray([[4, 1, 2, NB]], jnp.int32)
    tail0 = {"k": jnp.zeros((L, 1, Z, C, K, hd), jnp.float32),
             "v": jnp.zeros((L, 1, Z, C, K, hd), jnp.float32)}
    got = kvs.fold_pool(pool, tail0, tables,
                        jnp.asarray([0], jnp.int32),
                        jnp.asarray([2 * bs], jnp.int32), coeffs,
                        fold_cap=3 * bs)
    rows_k = jnp.concatenate([pool["k"][:, 4], pool["k"][:, 1]],
                             axis=1)              # (L, 2*bs, K, hd)
    rows_v = jnp.concatenate([pool["v"][:, 4], pool["v"][:, 1]], axis=1)
    ref = kvs.fold_rows(rows_k, rows_v,
                        jnp.arange(2 * bs, dtype=jnp.int32), coeffs, C)
    np.testing.assert_array_equal(np.asarray(got["k"][:, 0]),
                                  np.asarray(ref["k"]))
    np.testing.assert_array_equal(np.asarray(got["v"][:, 0]),
                                  np.asarray(ref["v"]))


# ---------------------------------------------------------------------------
# Lifecycle: retire / reuse / fork / speculative
# ---------------------------------------------------------------------------


def test_slot_reuse_after_sketched_retire(gemma):
    """A slot that served a folded long request is clean for its next
    occupant: the tail is re-zeroed at admission, so a short request
    decodes bitwise as on a fresh engine."""
    cfg, params = gemma
    bs = cfg.serve.kv_block_size
    sv = _serve(cfg, max_batch=1, max_seq=256, num_kv_blocks=24,
                kv_sketch_window=2 * bs)
    long_p, short_p = _prompts(cfg, [150, 11], seed=5)
    sched = SlotScheduler(cfg, params, serve=sv)
    sched.run([Request(rid=0, tokens=long_p, max_new=3)])
    assert sched._slot_first_lblk[0] == 0        # reset at retirement
    got = {c.rid: list(c.tokens)
           for c in sched.run([Request(rid=1, tokens=short_p, max_new=4)])}
    _, ref = _run(cfg, params, sv,
                  [Request(rid=1, tokens=short_p, max_new=4)])
    assert got == ref
    assert sched.decode_compilations == 1


def test_sketched_stream_mixed_with_exact(gemma):
    """Sketched and opted-out requests share one engine, one compiled
    chunk: the exact request's tokens match a sketch-free engine's,
    and everything completes."""
    cfg, params = gemma
    bs = cfg.serve.kv_block_size
    sv = _serve(cfg, max_batch=2, max_seq=256, num_kv_blocks=24,
                kv_sketch_window=2 * bs)
    pl, pe = _prompts(cfg, [140, 17], seed=9)
    reqs = [Request(rid=0, tokens=pl, max_new=4),
            Request(rid=1, tokens=pe, max_new=4, kv_sketch=False)]
    sched, got = _run(cfg, params, sv, reqs)
    assert set(got) == {0, 1} and all(len(v) == 4 for v in got.values())
    _, ref = _run(cfg, params, _serve(cfg, max_batch=1, max_seq=256,
                                      num_kv_blocks=24),
                  [Request(rid=1, tokens=pe, max_new=4)])
    assert got[1] == ref[1]
    assert sched.decode_compilations == 1


def test_speculative_sketch_identity_and_long_context(gemma):
    """Speculative engines compose: with window >= context the sketched
    spec engine's greedy output is bitwise a plain spec engine's; with a
    small window a long prompt still decodes (draft pool and tail fold
    in lockstep), one compilation each."""
    cfg, params = gemma
    sv0 = _serve(cfg, max_batch=2, max_seq=96, decode_chunk=2, spec_k=2)
    reqs = lambda: [Request(rid=i, tokens=p, max_new=5)
                    for i, p in enumerate(_prompts(cfg, [9, 18], seed=2))]
    _, ref = _run(cfg, params, sv0, reqs())
    s1, got = _run(cfg, params,
                   dataclasses.replace(sv0, kv_sketch_window=96), reqs())
    assert got == ref
    assert s1.decode_compilations == 1
    bs = cfg.serve.kv_block_size
    sv = _serve(cfg, max_batch=1, max_seq=256, decode_chunk=2, spec_k=2,
                num_kv_blocks=14, kv_sketch_window=4 * bs)
    (p,) = _prompts(cfg, [180], seed=4)
    s2, done = _run(cfg, params, sv, [Request(rid=0, tokens=p, max_new=6)])
    assert len(done[0]) == 6
    assert s2.decode_compilations == 1


def test_reseed_leaves_inflight_sketch_state(gemma):
    """reseed() swaps the base sampling key only — a queued sketched
    request admitted after the reseed still folds and completes."""
    cfg, params = gemma
    bs = cfg.serve.kv_block_size
    sv = _serve(cfg, max_batch=1, max_seq=256, num_kv_blocks=24,
                kv_sketch_window=2 * bs)
    (p,) = _prompts(cfg, [100], seed=6)
    sched = SlotScheduler(cfg, params, serve=sv)
    sched.submit(Request(rid=0, tokens=p, max_new=3, temperature=0.7,
                         top_k=4))
    sched.reseed(jax.random.PRNGKey(42))
    done = []
    while sched.pending:
        done.extend(sched.step())
    assert len(done) == 1 and len(done[0].tokens) == 3


# ---------------------------------------------------------------------------
# Shardings
# ---------------------------------------------------------------------------


def test_sketched_state_pspecs(gemma):
    """serve_state_pspecs covers the new state: tail tables put their
    bucket-column axis on the split-KV ("model") axis, fold_base rides
    the batch axis, and the spec tree matches the state tree."""
    from jax.sharding import PartitionSpec as P

    from repro.launch.shardings import serve_state_pspecs
    from repro.models.sharding import decode_rules

    cfg, params = gemma
    sv = _serve(cfg, kv_sketch_window=128, spec_k=2)
    sched = SlotScheduler(cfg, params, serve=sv)
    rules = decode_rules(multi_pod=False, long_context=False)
    specs = serve_state_pspecs(cfg, sched.state, rules)
    b = rules["batch"]
    assert specs.cache["tail"]["k"] == P(None, b, None, "model", None,
                                         None)
    assert specs.cache["draft"]["tail"]["k"] == \
        P(None, b, None, "model", None, None)
    assert specs.fold_base == P(b)
    # the spec tree must mirror the state tree exactly — a missing field
    # would silently replicate that array under shard_map
    assert (jax.tree.structure(sched.state)
            == jax.tree.structure(
                specs, is_leaf=lambda x: x is None or isinstance(x, P)))


# ---------------------------------------------------------------------------
# Pallas kernels vs oracles
# ---------------------------------------------------------------------------


def test_tail_fold_kernel_matches_oracle():
    from repro.kernels import kv_sketch as kk
    from repro.kernels import ref
    from repro.sketch.hashing import cached_coeffs

    rng = np.random.RandomState(0)
    Z, C, D, N, T = 3, 48, 64, 150, 200
    coeffs = cached_coeffs(7, Z)
    rows = jnp.asarray(rng.randn(N, D).astype(np.float32))
    pos = jnp.asarray(rng.randint(0, T, (N,)).astype(np.int32))
    tail = jnp.asarray(rng.randn(Z, C, D).astype(np.float32))
    got = kk.tail_fold(rows, pos, tail, coeffs, bN=64, bC=32)
    want = ref.kv_tail_fold_ref(rows, pos, tail, coeffs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4)


def test_tail_scores_kernel_matches_oracle():
    from repro.kernels import kv_sketch as kk
    from repro.kernels import ref
    from repro.sketch.hashing import cached_coeffs

    rng = np.random.RandomState(1)
    Z, C, D, N, T = 3, 32, 48, 20, 130
    coeffs = cached_coeffs(11, Z)
    q = jnp.asarray(rng.randn(N, D).astype(np.float32))
    tail_k = jnp.asarray(rng.randn(Z, C, D).astype(np.float32))
    got = kk.tail_scores(q, tail_k, coeffs, T=T, bN=16, bT=64)
    want = ref.kv_tail_scores_ref(q, tail_k, coeffs, T)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# Prefix-cache guard (bugfix)
# ---------------------------------------------------------------------------


def test_prefix_admit_rejects_freed_blocks(gemma):
    """The freed-block guard: admitting a prefix whose blocks have been
    returned to the pool must fail loudly — a ref on a freed block would
    resurrect it while the allocator hands the same block elsewhere."""
    cfg, params = gemma
    sched = SlotScheduler(cfg, params, serve=_serve(cfg))
    bs = sched.block_size
    ids = sched.alloc.alloc(2)
    prompt = np.arange(2 * bs, dtype=np.int32)
    sched.alloc.unref(ids)                      # freed: rc back to 0
    with pytest.raises(AssertionError, match="freed block"):
        sched.prefix_cache.admit(prompt, 2 * bs, tuple(ids))


def test_folded_prefix_never_admitted(gemma):
    """A sketched request whose qualifying prefix folded (and freed its
    leading blocks) must not register a prefix-cache entry — the entry
    would map prompt tokens to re-allocatable block ids."""
    cfg, params = gemma
    bs = cfg.serve.kv_block_size
    sv = _serve(cfg, max_batch=1, max_seq=256, num_kv_blocks=24,
                kv_sketch_window=2 * bs, admit_threshold=1)
    (p,) = _prompts(cfg, [120], seed=8)
    sched = SlotScheduler(cfg, params, serve=sv)
    for rid in range(3):
        sched.run([Request(rid=rid, tokens=p, max_new=2)])
    st = sched.prefix_cache.stats
    assert st.admitted == 0, st
