"""Training substrate: optimizer behaviour, FCS gradient compression with
error feedback, data determinism, checkpoint roundtrips."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SketchConfig
from repro.configs.registry import reduced_config
from repro.models import model as M
from repro.train import checkpoint as ckpt
from repro.train.data import make_batch
from repro.train.grad_compress import (LeafCodec, _leaf_codecs,
                                       compress_roundtrip, sketch_leaf)
from repro.train.loop import train
from repro.train.optimizer import adamw_init, adamw_update


def test_adamw_minimizes_quadratic():
    w = {"w": jnp.array([5.0, -3.0, 2.0])}
    opt = adamw_init(w)
    for _ in range(200):
        g = jax.tree.map(lambda x: 2 * x, w)     # grad of ||w||^2
        w, opt = adamw_update(g, opt, w, lr=5e-2, weight_decay=0.0)
    assert float(jnp.linalg.norm(w["w"])) < 0.2


def test_grad_compression_unbiased():
    g = jax.random.normal(jax.random.PRNGKey(0), (1 << 16,))
    codecs, flat = _leaf_codecs({"g": g}, ratio=16, seed=0)
    c = flat[0]
    assert isinstance(c, LeafCodec)
    sk = sketch_leaf(g, c, jax.random.PRNGKey(0))
    assert sk.shape[0] == c.Jt
    assert g.size / sk.size > 12          # compression ratio ~ ratio
    # unbiasedness: mean of estimates over fresh hashes approaches g
    acc = jnp.zeros_like(g)
    n = 48
    for t in range(n):
        gh, _ = compress_roundtrip(g, jnp.zeros((1,)), c,
                                   jax.random.PRNGKey(t))
        acc = acc + gh
    est = acc / n
    # noise std per coord ~ sqrt((k-1)/n) * ||g||/sqrt(dim) = ~0.56
    err = float(jnp.linalg.norm(est - g) / jnp.linalg.norm(g))
    assert err < 0.85, err
    corr = float(jnp.vdot(est, g) / (jnp.linalg.norm(est)
                                     * jnp.linalg.norm(g)))
    assert corr > 0.75, corr


def test_compressed_sgd_converges():
    """Unbiased compressed SGD minimizes a quadratic with lr ~ 1/(1+omega)
    (omega ~ ratio collision variance)."""
    dim = 1 << 16
    target = jax.random.normal(jax.random.PRNGKey(1), (dim,))
    x = jnp.zeros((dim,))
    _, flat = _leaf_codecs({"x": x}, ratio=32, seed=1)
    c = flat[0]

    @jax.jit
    def step(x, t):
        g = x - target
        ghat, _ = compress_roundtrip(g, jnp.zeros((1,)), c,
                                     jax.random.PRNGKey(t))
        return x - (0.5 / 32) * ghat
    for t in range(1200):
        x = step(x, t)
    rel = float(jnp.linalg.norm(x - target) / jnp.linalg.norm(target))
    assert rel < 0.1, rel


def test_data_determinism():
    cfg = reduced_config("yi-9b")
    b1 = make_batch(cfg, 7, 4, 32, seed=3)
    b2 = make_batch(cfg, 7, 4, 32, seed=3)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = make_batch(cfg, 8, 4, 32, seed=3)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_checkpoint_roundtrip(tmp_path):
    cfg = reduced_config("gemma-2b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    state = {"params": params, "opt": opt}
    ckpt.save(str(tmp_path), 5, state)
    assert ckpt.latest_step(str(tmp_path)) == 5
    step, restored = ckpt.restore(str(tmp_path), state)
    assert step == 5
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_trainer_learns():
    cfg = reduced_config("yi-9b")
    h = train(cfg, steps=120, batch=8, seq=64, lr=1e-2, log_every=1000,
              log_fn=lambda *_: None)
    assert h.losses[-1] < h.losses[0] - 0.3


@pytest.mark.slow
def test_trainer_learns_compressed():
    cfg = dataclasses.replace(
        reduced_config("yi-9b"),
        sketch=SketchConfig(grad_compression=True, grad_hash_ratio=8))
    h = train(cfg, steps=120, batch=8, seq=64, lr=1e-2, log_every=1000,
              log_fn=lambda *_: None)
    assert h.losses[-1] < h.losses[0] - 0.2


def test_resume_is_bitwise(tmp_path):
    cfg = reduced_config("gemma-2b")
    d = str(tmp_path / "run")
    # full run
    h_full = train(cfg, steps=20, batch=2, seq=32, lr=1e-3, ckpt_dir=None,
                   log_every=1000, log_fn=lambda *_: None)
    # interrupted run: ckpt at step 10, then resume
    train(cfg, steps=10, batch=2, seq=32, lr=1e-3, ckpt_dir=d,
          ckpt_every=10, log_every=1000, log_fn=lambda *_: None)
    h_res = train(cfg, steps=20, batch=2, seq=32, lr=1e-3, ckpt_dir=d,
                  ckpt_every=100, resume=True, log_every=1000,
                  log_fn=lambda *_: None)
    np.testing.assert_allclose(h_full.losses[10:], h_res.losses,
                               rtol=1e-5, atol=1e-6)
