"""Speculative decoding subsystem: draft derivation (truncated /
count-sketch-compressed), multi-query verification, greedy-identity
guarantees across spec_k / mixed batches / prefix-cache hits, and
copy-on-write protection of shared pool blocks."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import reduced_config
from repro.models import draft as dr
from repro.models import model as M
from repro.models import transformer as tf
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import Request, SlotScheduler


@pytest.fixture(scope="module")
def gemma():
    cfg = reduced_config("gemma-2b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _requests(cfg, rng, lens, max_new=5, **kw):
    return [Request(rid=i,
                    tokens=rng.randint(0, cfg.vocab_size, (n,)).astype(
                        np.int32),
                    max_new=max_new, **kw)
            for i, n in enumerate(lens)]


def _serve(cfg, **kw):
    base = dict(max_batch=2, max_seq=96, decode_chunk=4, prefill_bucket=16,
                prefix_block=16, kv_block_size=16, admit_threshold=100)
    base.update(kw)
    return dataclasses.replace(cfg.serve, **base)


# ---------------------------------------------------------------------------
# Draft derivation
# ---------------------------------------------------------------------------


def test_truncate_params_slices_block_stack(gemma):
    cfg, params = gemma
    dparams, dcfg = dr.truncate_params(params, cfg, 1)
    assert dcfg.num_layers == 1
    for leaf, dleaf in zip(jax.tree.leaves(params["blocks"]),
                           jax.tree.leaves(dparams["blocks"])):
        assert dleaf.shape == (1,) + leaf.shape[1:]
        np.testing.assert_array_equal(np.asarray(dleaf[0]),
                                      np.asarray(leaf[0]))
    # embed / head / final_norm are shared, not copied
    assert dparams["embed"] is params["embed"]
    with pytest.raises(ValueError):
        dr.truncate_params(params, cfg, cfg.num_layers + 1)


def test_compress_params_sketches_weights_and_head(gemma):
    """ratio > 1 count-sketch-compresses block matmuls along their
    contraction dim (same shapes back, different values) and swaps the
    head for the FCS-sketched (J, padded_vocab) projection wired through
    cfg.sketch.sketched_head."""
    cfg, params = gemma
    dparams, dcfg = dr.compress_params(params, cfg, 2, ratio=2)
    assert dcfg.sketch.sketched_head
    J = cfg.d_model // 2
    assert dcfg.sketch.head_hash_len == J
    assert dparams["head"].shape == (J, cfg.padded_vocab)
    wq = np.asarray(params["blocks"]["attn"]["wq"][:2], np.float32)
    dwq = np.asarray(dparams["blocks"]["attn"]["wq"], np.float32)
    assert dwq.shape == wq.shape
    assert not np.array_equal(dwq, wq)
    # the reconstruction is an approximation, not noise: it correlates
    # strongly with the original weight
    corr = np.corrcoef(wq.ravel(), dwq.ravel())[0, 1]
    assert corr > 0.5, corr
    # norms pass through untouched
    np.testing.assert_array_equal(
        np.asarray(dparams["blocks"]["norm1"]),
        np.asarray(params["blocks"]["norm1"][:2]))
    # ratio <= 1 degenerates to pure truncation
    tparams, tcfg = dr.compress_params(params, cfg, 2, ratio=0)
    assert not tcfg.sketch.sketched_head
    np.testing.assert_array_equal(
        np.asarray(tparams["blocks"]["attn"]["wq"]), wq)


def test_cs_reconstruction_error_shrinks_with_buckets():
    """More sketch buckets (lower ratio) -> lower reconstruction error:
    the count-sketch collision noise scales down with J."""
    w = jax.random.normal(jax.random.PRNGKey(3), (256, 32))
    errs = []
    for ratio in (8, 2):
        w2 = dr._cs_reconstruct(w, ratio, rows=3, seed=7)
        errs.append(float(jnp.linalg.norm(w2 - w) / jnp.linalg.norm(w)))
    assert errs[1] < errs[0], errs


def test_make_draft_gating(gemma):
    cfg, params = gemma
    assert dr.make_draft(params, cfg, _serve(cfg, spec_k=0)) is None
    d = dr.make_draft(params, cfg, _serve(cfg, spec_k=2, draft_depth=1))
    assert d is not None and d.cfg.num_layers == 1
    ssm = reduced_config("xlstm-1.3b")
    sparams = M.init_params(jax.random.PRNGKey(0), ssm)
    assert dr.make_draft(sparams, ssm,
                         dataclasses.replace(ssm.serve, spec_k=2)) is None


# ---------------------------------------------------------------------------
# verify_step
# ---------------------------------------------------------------------------


def test_verify_step_matches_sequential_decode(gemma):
    """The foundation of greedy identity: verify logits at position
    index+i are bitwise what a plain decode step produces after
    committing the first i+1 tokens, and the committed KV rows match."""
    cfg, params = gemma
    B, bs, nbs = 2, 8, 6
    NB = B * nbs
    rng = np.random.RandomState(5)
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, 4)), jnp.int32)
    pos0 = jnp.asarray([3, 1], jnp.int32)
    tables = jnp.arange(NB, dtype=jnp.int32).reshape(B, nbs)

    cache_v = tf.init_paged_cache(cfg, NB, bs)
    ver = jax.jit(functools.partial(tf.verify_step, cfg=cfg))
    lg_v, cache_v = ver(params, cache_v, toks, pos0, tables=tables)

    cache_d = tf.init_paged_cache(cfg, NB, bs)
    dec = jax.jit(functools.partial(tf.decode_step, cfg=cfg))
    for i in range(toks.shape[1]):
        lg_d, cache_d = dec(params, cache_d, toks[:, i:i + 1], pos0 + i,
                            tables=tables)
        np.testing.assert_array_equal(np.asarray(lg_v[:, i]),
                                      np.asarray(lg_d),
                                      err_msg=f"position offset {i}")
    np.testing.assert_array_equal(np.asarray(cache_v["kv"]["k"]),
                                  np.asarray(cache_d["kv"]["k"]))


# ---------------------------------------------------------------------------
# Greedy identity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec_k,depth,ratio", [(1, 1, 0), (3, 1, 0),
                                                (3, 2, 2)])
def test_spec_greedy_identity(gemma, spec_k, depth, ratio):
    """Speculative greedy output is token-for-token identical to plain
    greedy decode for any spec_k and any draft (shallow or count-sketch-
    compressed — acceptance varies, correctness must not), with decode
    compiled exactly once."""
    cfg, params = gemma
    rng = np.random.RandomState(6)
    lens = [5, 16, 9, 23]
    reqs = _requests(cfg, rng, lens, max_new=5)
    ref = {c.rid: c.tokens
           for c in SlotScheduler(cfg, params,
                                  serve=_serve(cfg)).run(list(reqs))}
    sched = SlotScheduler(cfg, params, serve=_serve(
        cfg, spec_k=spec_k, draft_depth=depth, draft_sketch_ratio=ratio))
    done = {c.rid: c.tokens for c in sched.run(list(reqs))}
    for r in reqs:
        np.testing.assert_array_equal(done[r.rid], ref[r.rid],
                                      err_msg=f"rid {r.rid}")
    assert sched.decode_compilations == 1
    assert sched.prefill_compilations == 1


def test_spec_mixed_batch_identity(gemma):
    """Mixed spec / non-spec (per-request spec_k=0) / sampled requests in
    ONE stream share the single compiled chunk; every greedy request
    still matches plain decode bitwise and the sampled request stays
    in-vocab."""
    cfg, params = gemma
    rng = np.random.RandomState(7)
    reqs = [Request(rid=0, tokens=rng.randint(0, cfg.vocab_size, (12,)
                                              ).astype(np.int32),
                    max_new=5),                          # speculates
            Request(rid=1, tokens=rng.randint(0, cfg.vocab_size, (7,)
                                              ).astype(np.int32),
                    max_new=5, spec_k=0),                # plain greedy
            Request(rid=2, tokens=rng.randint(0, cfg.vocab_size, (9,)
                                              ).astype(np.int32),
                    max_new=5, temperature=0.8, top_k=4, seed=3),
            Request(rid=3, tokens=rng.randint(0, cfg.vocab_size, (19,)
                                              ).astype(np.int32),
                    max_new=5, spec_k=2)]                # clamped k
    ref = {c.rid: c.tokens
           for c in SlotScheduler(cfg, params, serve=_serve(
               cfg, max_batch=3)).run(list(reqs))}
    sched = SlotScheduler(cfg, params, serve=_serve(
        cfg, max_batch=3, spec_k=3, draft_depth=1))
    done = {c.rid: c.tokens for c in sched.run(list(reqs))}
    assert sched.decode_compilations == 1
    for r in reqs:
        if (r.temperature or 0) == 0:
            np.testing.assert_array_equal(done[r.rid], ref[r.rid],
                                          err_msg=f"rid {r.rid}")
    assert int(np.max(done[2])) < cfg.vocab_size


def test_spec_budget_clip_identity(gemma):
    """A request whose accepted run would overshoot its token budget is
    clipped mid-round: exactly max_new tokens come back and they match
    plain decode."""
    cfg, params = gemma
    rng = np.random.RandomState(8)
    reqs = _requests(cfg, rng, [10, 6], max_new=3)
    ref = {c.rid: c.tokens
           for c in SlotScheduler(cfg, params,
                                  serve=_serve(cfg)).run(list(reqs))}
    sched = SlotScheduler(cfg, params,
                          serve=_serve(cfg, spec_k=6, draft_depth=2))
    done = {c.rid: c.tokens for c in sched.run(list(reqs))}
    for r in reqs:
        assert len(done[r.rid]) == 3
        np.testing.assert_array_equal(done[r.rid], ref[r.rid])


def test_spec_prefix_hit_identity_and_cow(gemma):
    """The acceptance-criteria CoW test: a cached full-prompt prefix
    entry's pool blocks (target AND draft pools) are bitwise unmodified
    after a hitting slot speculates past them — the boundary block is
    forked, never written in place — and the hit's output equals the
    cold miss."""
    cfg, params = gemma
    sv = _serve(cfg, spec_k=3, draft_depth=1, admit_threshold=2)
    sched = SlotScheduler(cfg, params, serve=sv)
    rng = np.random.RandomState(9)
    prompt = rng.randint(0, cfg.vocab_size, (32,)).astype(np.int32)
    outs = [sched.run([Request(rid=i, tokens=prompt, max_new=6)])[0]
            for i in range(2)]
    key = tuple(int(t) for t in prompt)
    ids = list(sched.prefix_cache._entries[key].block_ids)
    assert len(ids) == 2                  # full 32-token prompt cached
    snap = {(name, sub): np.asarray(pool[sub])[:, ids].copy()
            for name, pool in (("kv", sched.state.cache["kv"]),
                               ("draft", sched.state.cache["draft"]["kv"]))
            for sub in ("k", "v")}
    hit = sched.run([Request(rid=9, tokens=prompt, max_new=6)])[0]
    assert hit.prefix_hit
    np.testing.assert_array_equal(hit.tokens, outs[0].tokens)
    for (name, pool) in (("kv", sched.state.cache["kv"]),
                         ("draft", sched.state.cache["draft"]["kv"])):
        for sub in ("k", "v"):
            np.testing.assert_array_equal(
                np.asarray(pool[sub])[:, ids], snap[(name, sub)],
                err_msg=f"speculation mutated cached {name}/{sub} blocks")
    # the hitting slot forked the boundary block: after it retired, only
    # the cache holds the entry's blocks
    assert all(int(sched.alloc.rc[b]) == 1 for b in ids)
    assert sched.alloc.reserved == sched.prefix_cache.held_blocks()


def test_spec_acceptance_ceiling(gemma):
    """When the target's upper layers contribute nothing (zeroed
    residual outputs), the truncated draft agrees with the target
    exactly: every proposal is accepted and each round advances
    spec_k + 1 tokens — verification and acceptance bookkeeping work."""
    cfg, _ = gemma
    cfg6 = dataclasses.replace(cfg, num_layers=4)
    params = M.init_params(jax.random.PRNGKey(2), cfg6)
    params["blocks"]["attn"]["wo"] = \
        params["blocks"]["attn"]["wo"].at[1:].set(0)
    params["blocks"]["ffn"]["w_down"] = \
        params["blocks"]["ffn"]["w_down"].at[1:].set(0)
    sched = SlotScheduler(cfg6, params, serve=_serve(
        cfg6, spec_k=4, draft_depth=1, decode_chunk=2))
    rng = np.random.RandomState(10)
    sched.run(_requests(cfg6, rng, [10, 8], max_new=10))
    assert sched.acceptance_rate == 1.0
    assert sched.mean_accepted_run == 5.0


def test_engine_spec_k_scalar_or_vector(gemma):
    """ServeEngine.generate carries spec_k like temperature: scalar or
    per-request vector, greedy outputs identical to a plain engine."""
    cfg, params = gemma
    scfg = dataclasses.replace(
        cfg, serve=dataclasses.replace(cfg.serve, spec_k=3, draft_depth=1))
    rng = np.random.RandomState(11)
    prompts = jnp.asarray(rng.randint(0, cfg.vocab_size, (3, 12)),
                          jnp.int32)
    ref = ServeEngine(cfg, params, max_seq=96).generate(
        prompts, max_new=5).tokens
    eng = ServeEngine(scfg, params, max_seq=96)
    got = eng.generate(prompts, max_new=5, spec_k=[3, 0, 2]).tokens
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    assert eng.decode_compilations == 1
    got2 = eng.generate(prompts, max_new=5).tokens   # engine default k
    np.testing.assert_array_equal(np.asarray(got2), np.asarray(ref))
    assert eng.decode_compilations == 1


def test_spec_state_pspecs(gemma):
    """Speculative engine state placement: the draft's shallow pool takes
    the same split-KV block-axis spec as the target pool, spec_k rides
    the batch axis, and draft params get the weight-stationary TP map."""
    from jax.sharding import PartitionSpec as P

    from repro.launch.shardings import draft_param_pspecs, serve_state_pspecs
    from repro.models.sharding import decode_rules

    cfg, params = gemma
    sched = SlotScheduler(cfg, params, serve=_serve(
        cfg, spec_k=2, draft_depth=1, draft_sketch_ratio=2))
    rules = decode_rules(multi_pod=False, long_context=False)
    specs = serve_state_pspecs(cfg, sched.state, rules)
    assert specs.cache["kv"]["k"] == P(None, "model", None, None, None)
    assert specs.cache["draft"]["kv"]["k"] == \
        P(None, "model", None, None, None)
    assert specs.spec_k == P(rules["batch"])
    dspecs = draft_param_pspecs(sched.draft, rules)
    # the FCS-sketched draft head (J, padded_vocab): vocab over "model",
    # the small sketch dim replicated — the dense head's placement
    assert dspecs["head"] == P(None, "model")
    assert dspecs["blocks"]["attn"]["wq"] == P(None, None, "model")
