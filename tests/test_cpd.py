"""CPD algorithms: exact recovery on clean tensors; sketched variants
preserve the paper's accuracy ordering (FCS >= TS at equal hashes)."""
import jax
import jax.numpy as jnp
import pytest

from repro.cpd.als import als_decompose, als_residual
from repro.cpd.rtpm import (cp_reconstruct, plain_oracle, residual_norm,
                            rtpm, rtpm_decompose)

KEY = jax.random.PRNGKey(0)


def _sym_tensor(I, R, lams=None, noise=0.0, key=KEY):
    Q, _ = jnp.linalg.qr(jax.random.normal(key, (I, I)))
    U = Q[:, :R]
    lams = jnp.arange(R, 0, -1).astype(jnp.float32) if lams is None else lams
    T = jnp.einsum("r,ar,br,cr->abc", lams, U, U, U)
    if noise:
        T = T + noise * jax.random.normal(key, T.shape)
    return T, lams, U


def test_rtpm_exact_on_clean_tensor():
    T, lams, U = _sym_tensor(25, 3)
    tiuu, tuuu = plain_oracle(T)
    lh, Uh = rtpm(tiuu, tuuu, 25, 3, KEY, n_inits=8, n_iters=15)
    assert float(jnp.max(jnp.abs(jnp.sort(lh) - jnp.sort(lams)))) < 1e-3
    assert float(residual_norm(T, lh, Uh)) < 1e-3


def test_rtpm_noisy_plain_reaches_noise_floor():
    T, lams, U = _sym_tensor(30, 5, lams=jnp.ones(5), noise=0.005)
    Tc = jnp.einsum("r,ar,br,cr->abc", jnp.ones(5), U, U, U)
    lh, Uh = rtpm_decompose(T, 5, KEY, method="plain", n_inits=10,
                            n_iters=15)
    clean_res = float(jnp.linalg.norm(Tc - cp_reconstruct(lh, Uh))
                      / jnp.linalg.norm(Tc))
    assert clean_res < 0.12


@pytest.mark.slow
def test_rtpm_fcs_beats_ts_at_equal_hashes():
    """Prop. 1 consequence at the application level (paper Fig. 1/Table 2
    ordering).  Averaged over seeds to damp variance."""
    T, lams, U = _sym_tensor(30, 4, lams=jnp.ones(4), noise=0.005)
    Tc = jnp.einsum("r,ar,br,cr->abc", jnp.ones(4), U, U, U)
    nc = jnp.linalg.norm(Tc)

    def run(method, seed):
        lh, Uh = rtpm_decompose(T, 4, jax.random.PRNGKey(seed),
                                method=method, hash_len=700, n_sketches=10,
                                n_inits=10, n_iters=15)
        return float(jnp.linalg.norm(Tc - cp_reconstruct(lh, Uh)) / nc)

    fcs = sum(run("fcs", s) for s in range(3)) / 3
    ts = sum(run("ts", s) for s in range(3)) / 3
    assert fcs <= ts * 1.15, (fcs, ts)


def test_als_exact_on_clean_tensor():
    ks = jax.random.split(KEY, 3)
    A0 = jnp.linalg.qr(jax.random.normal(ks[0], (20, 20)))[0][:, :4]
    B0 = jnp.linalg.qr(jax.random.normal(ks[1], (20, 20)))[0][:, :4]
    C0 = jnp.linalg.qr(jax.random.normal(ks[2], (20, 20)))[0][:, :4]
    T = jnp.einsum("ar,br,cr->abc", A0, B0, C0)
    lam, F = als_decompose(T, 4, KEY, method="plain", n_iters=25)
    assert float(als_residual(T, lam, F)) < 1e-2


@pytest.mark.slow
def test_als_fcs_beats_ts():
    ks = jax.random.split(KEY, 3)
    A0 = jnp.linalg.qr(jax.random.normal(ks[0], (30, 30)))[0][:, :6]
    B0 = jnp.linalg.qr(jax.random.normal(ks[1], (30, 30)))[0][:, :6]
    C0 = jnp.linalg.qr(jax.random.normal(ks[2], (30, 30)))[0][:, :6]
    T = jnp.einsum("ar,br,cr->abc", A0, B0, C0) \
        + 0.01 * jax.random.normal(KEY, (30, 30, 30))

    def run(method, seed):
        lam, F = als_decompose(T, 6, jax.random.PRNGKey(seed),
                               method=method, hash_len=1200, n_sketches=8,
                               n_iters=10)
        return float(als_residual(T, lam, F))

    fcs = sum(run("fcs", s) for s in range(2)) / 2
    ts = sum(run("ts", s) for s in range(2)) / 2
    assert fcs <= ts * 1.1, (fcs, ts)
