"""HLO analyzer unit tests: trip-count weighting, collective math, fusion
slice-awareness — validated on a freshly compiled toy module in a
subprocess (device count must differ from the main test process)."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.launch.hlo_analysis import (_collective_traffic,
                                       _shape_elems_bytes, roofline_terms)


def test_shape_bytes():
    assert _shape_elems_bytes("bf16[4,8]{1,0}") == (32, 64)
    assert _shape_elems_bytes("(f32[2,2], s32[3])") == (7, 28)
    assert _shape_elems_bytes("pred[]") == (1, 1)


def test_collective_traffic_models():
    # ring all-reduce: 2x(g-1)/g of buffer
    assert _collective_traffic("all-reduce", 1024, 4) == 2 * 1024 * 3 / 4
    assert _collective_traffic("all-gather", 1024, 4) == 1024 * 3 / 4
    assert _collective_traffic("reduce-scatter", 256, 4) == 256 * 3
    assert _collective_traffic("collective-permute", 77, 2) == 77
    assert _collective_traffic("all-reduce", 1024, 1) == 0


def test_roofline_terms_dominance():
    t = roofline_terms(197e12, 0.0, {"ici_bytes": 0.0, "dcn_bytes": 0.0})
    assert t["dominant"] == "compute"
    assert abs(t["t_compute_s"] - 1.0) < 1e-9
    t = roofline_terms(0.0, 819e9, {"ici_bytes": 50e9, "dcn_bytes": 0.0})
    assert t["dominant"] in ("memory", "collective")


SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    import sys
    sys.path.insert(0, "src")
    from repro.launch.hlo_analysis import analyze
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    def f(x, w):
        def body(c, wl):
            c = jnp.tanh(c @ wl)
            c = jax.lax.with_sharding_constraint(c, P("data", "model"))
            return c, ()
        y, _ = jax.lax.scan(body, x, w)
        return y.sum()
    xs = jax.ShapeDtypeStruct((16, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    with mesh:
        comp = jax.jit(f).lower(xs, ws).compile()
    c = analyze(comp.as_text())
    # 5 iterations x dot(8x64 @ 64x16) = 5 * 2*8*16*64 flops
    assert abs(c["flops"] - 5 * 2 * 8 * 16 * 64) < 1e-6, c["flops"]
    # 5 iterations x all-gather f32[8,64] with group 4 -> 3/4 buffer
    assert abs(c["ici_bytes"] - (5 * 8 * 64 * 4 * 3 / 4
                                 + c["per_op"].get("all-reduce", 0))) < 1e-3
    print("OK")
""")


@pytest.mark.slow
def test_trip_count_weighting_end_to_end(tmp_path):
    p = subprocess.run([sys.executable, "-c", SCRIPT],
                       capture_output=True, text=True,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert p.returncode == 0, p.stderr[-2000:]
    assert "OK" in p.stdout
