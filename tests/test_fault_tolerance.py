"""Fault tolerance: kill a trainer subprocess mid-run, restart, verify the
loss trajectory continues from the checkpoint (crash-restart semantics)."""
import os
import re
import subprocess
import sys

import pytest

ENV = dict(os.environ, PYTHONPATH="src")


def _run(args, check=True):
    p = subprocess.run([sys.executable, "-m", "repro.launch.train"] + args,
                       capture_output=True, text=True, env=ENV,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    if check and p.returncode != 0:
        raise AssertionError(p.stderr[-2000:])
    return p


@pytest.mark.slow
def test_crash_and_resume(tmp_path):
    ckpt = str(tmp_path / "ft")
    # run that crashes at step 15 (checkpoint cadence 10)
    p = _run(["--arch", "gemma-2b", "--steps", "30", "--batch", "2",
              "--seq", "32", "--ckpt-dir", ckpt, "--ckpt-every", "10",
              "--crash-at-step", "15"], check=False)
    assert p.returncode != 0
    assert "injected crash" in p.stderr
    assert os.path.exists(os.path.join(ckpt, "LATEST"))
    with open(os.path.join(ckpt, "LATEST")) as f:
        assert int(f.read()) == 10
    # resume to completion
    p2 = _run(["--arch", "gemma-2b", "--steps", "30", "--batch", "2",
               "--seq", "32", "--ckpt-dir", ckpt, "--ckpt-every", "10",
               "--resume"])
    assert "FINAL loss=" in p2.stdout
    m = re.search(r"steps=(\d+)", p2.stdout)
    assert int(m.group(1)) == 20  # resumed from 10, ran 10..29
