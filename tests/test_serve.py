"""Serving engine: batched greedy generation is deterministic and matches
teacher-forced full-forward argmax continuation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import reduced_config
from repro.models import layers as ly
from repro.models import model as M
from repro.models import transformer as tf
from repro.serve.engine import ServeEngine


@pytest.mark.parametrize("arch", ["yi-9b", "gemma-2b"])
def test_greedy_matches_full_forward(arch):
    cfg = reduced_config(arch)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, max_seq=64)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                 cfg.vocab_size)
    res = engine.generate(prompts, max_new=6)

    # teacher-forced reference: append generated tokens, re-run full fwd
    seq = prompts
    for t in range(6):
        b = {"tokens": seq}
        y, _, _ = tf.forward(params, tf.embed_inputs(params, b, cfg), cfg,
                             mode="train")
        lg = ly.logits_fn(params, y[:, -1:], cfg)[:, 0, :cfg.vocab_size]
        nxt = jnp.argmax(lg, axis=-1)
        np.testing.assert_array_equal(np.asarray(res.tokens[:, t]),
                                      np.asarray(nxt))
        seq = jnp.concatenate([seq, nxt[:, None].astype(jnp.int32)], axis=1)


def test_generation_deterministic():
    cfg = reduced_config("gemma-2b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, max_seq=48)
    prompts = jnp.ones((3, 8), jnp.int32)
    a = engine.generate(prompts, max_new=4).tokens
    b = engine.generate(prompts, max_new=4).tokens
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
