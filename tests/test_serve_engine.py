"""Continuous-batching engine: slot-cache decode correctness against
per-request full-context recompute (all families), chunked prefill,
per-request sampling, single decode compilation for mixed request
streams, and count-min gated prefix caching."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import reduced_config
from repro.models import layers as ly
from repro.models import model as M
from repro.models import transformer as tf
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import Request, SlotScheduler
from repro.sketch import csvec


@pytest.fixture(scope="module")
def gemma():
    cfg = reduced_config("gemma-2b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _oracle_continuation(cfg, params, prompt: np.ndarray, n: int):
    """Teacher-forced greedy continuation via full-context recompute."""
    seq = jnp.asarray(prompt, jnp.int32)[None, :]
    out = []
    for _ in range(n):
        y, _, _ = tf.forward(params, tf.embed_inputs(
            params, {"tokens": seq}, cfg), cfg, mode="train")
        lg = ly.logits_fn(params, y[:, -1:], cfg)[:, 0, :cfg.vocab_size]
        nxt = int(jnp.argmax(lg, axis=-1)[0])
        out.append(nxt)
        seq = jnp.concatenate(
            [seq, jnp.full((1, 1), nxt, jnp.int32)], axis=1)
    return out


def test_mixed_length_stream_matches_recompute_and_compiles_once(gemma):
    """The tentpole contract: a stream of mixed-length, mixed-budget
    requests through the chunk-prefilled slot cache decodes
    token-for-token identically to per-request full-context recompute,
    while decode AND chunked prefill each compile exactly once."""
    cfg, params = gemma
    serve = dataclasses.replace(cfg.serve, max_batch=3, max_seq=96,
                                decode_chunk=4, prefill_bucket=16)
    sched = SlotScheduler(cfg, params, serve=serve)
    rng = np.random.RandomState(0)
    lens = [5, 16, 9, 23, 31, 12]
    reqs = [Request(rid=i,
                    tokens=rng.randint(0, cfg.vocab_size, (n,)).astype(
                        np.int32),
                    max_new=3 + i % 3)
            for i, n in enumerate(lens)]
    done = {c.rid: c for c in sched.run(list(reqs))}
    assert len(done) == len(reqs)
    for r in reqs:
        ref = _oracle_continuation(cfg, params, r.tokens, r.max_new)
        np.testing.assert_array_equal(done[r.rid].tokens, ref,
                                      err_msg=f"rid {r.rid}")
    assert sched.decode_compilations == 1
    assert sched.prefill_compilations == 1


@pytest.mark.parametrize("arch", ["xlstm-1.3b", "zamba2-2.7b"])
def test_recurrent_slot_stream_matches_recompute(arch):
    """ssm / hybrid requests ride the slot scheduler (no synchronized
    fallback): mixed-length streams — including a 1-token prompt, which
    exercises the zero-state slot reset — match full-context recompute
    token-for-token, with one decode compilation."""
    cfg = reduced_config(arch)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    serve = dataclasses.replace(cfg.serve, max_batch=2, max_seq=48,
                                decode_chunk=4)
    sched = SlotScheduler(cfg, params, serve=serve)
    rng = np.random.RandomState(0)
    reqs = [Request(rid=i,
                    tokens=rng.randint(0, cfg.vocab_size, (n,)).astype(
                        np.int32),
                    max_new=3)
            for i, n in enumerate([6, 11, 1, 9])]
    done = {c.rid: c for c in sched.run(list(reqs))}
    for r in reqs:
        ref = _oracle_continuation(cfg, params, r.tokens, r.max_new)
        np.testing.assert_array_equal(done[r.rid].tokens, ref,
                                      err_msg=f"{arch} rid {r.rid}")
    assert sched.decode_compilations == 1


def test_chunked_prefill_hit_matches_miss_multi_bucket(gemma):
    """A cached-prefix hit whose uncached suffix spans MULTIPLE prefill
    buckets is chunk-prefilled against the slot cache and reproduces the
    cold-miss output token-for-token; decode and prefill each stay at one
    compilation."""
    cfg, params = gemma
    serve = dataclasses.replace(cfg.serve, max_batch=2, max_seq=96,
                                prefill_bucket=8, prefix_block=16,
                                admit_threshold=2)
    sched = SlotScheduler(cfg, params, serve=serve)
    rng = np.random.RandomState(1)
    prompt = np.concatenate([
        rng.randint(0, cfg.vocab_size, (16,)).astype(np.int32),   # prefix
        rng.randint(0, cfg.vocab_size, (20,)).astype(np.int32)])  # suffix
    assert len(prompt) - 16 > serve.prefill_bucket   # suffix > 1 bucket
    outs = []
    for i in range(4):
        done = sched.run([Request(rid=i, tokens=prompt, max_new=5)])
        outs.append(done[0])
    st = sched.prefix_cache.stats
    assert st.admitted >= 1 and st.hits >= 1
    assert outs[-1].prefix_hit and not outs[0].prefix_hit
    for o in outs[1:]:
        np.testing.assert_array_equal(o.tokens, outs[0].tokens)
    np.testing.assert_array_equal(
        outs[0].tokens, _oracle_continuation(cfg, params, prompt, 5))
    assert sched.decode_compilations == 1
    assert sched.prefill_compilations == 1


def test_prefix_cache_respects_byte_budget(gemma):
    """LRU eviction keeps cached KV bytes at or under the configured
    budget no matter how many prefixes qualify for admission."""
    cfg, params = gemma
    serve = dataclasses.replace(cfg.serve, max_batch=2, max_seq=96,
                                prefill_bucket=16, prefix_block=16,
                                admit_threshold=1,
                                prefix_cache_bytes=6 * 1024)
    sched = SlotScheduler(cfg, params, serve=serve)
    rng = np.random.RandomState(2)
    for i in range(6):
        prompt = rng.randint(0, cfg.vocab_size, (16,)).astype(np.int32)
        sched.run([Request(rid=i, tokens=prompt, max_new=2)])
    st = sched.prefix_cache.stats
    assert st.admitted >= 2
    assert st.evicted >= 1
    assert st.bytes <= serve.prefix_cache_bytes
    # recompute from entries agrees with the running counter
    live = sum(e.nbytes for e in sched.prefix_cache._entries.values())
    assert live == st.bytes


def test_exact_length_prefill_still_hits(gemma):
    """prefill_bucket=1 (exact-length chunks, the documented moe setting)
    must not disable prefix-cache hits — chunked prefill degenerates to
    token-by-token but the hit path still works."""
    cfg, params = gemma
    serve = dataclasses.replace(cfg.serve, max_batch=2, max_seq=96,
                                prefill_bucket=1, prefix_block=8,
                                admit_threshold=2)
    sched = SlotScheduler(cfg, params, serve=serve)
    rng = np.random.RandomState(3)
    prompt = rng.randint(0, cfg.vocab_size, (13,)).astype(np.int32)
    outs = [sched.run([Request(rid=i, tokens=prompt, max_new=4)])[0]
            for i in range(4)]
    assert sched.prefix_cache.stats.hits >= 1
    assert outs[-1].prefix_hit
    for o in outs[1:]:
        np.testing.assert_array_equal(o.tokens, outs[0].tokens)
    np.testing.assert_array_equal(
        outs[0].tokens, _oracle_continuation(cfg, params, prompt, 4))


def test_mixed_per_request_sampling_one_compilation(gemma):
    """Greedy and sampled requests share one compiled chunk: a mixed
    temperature/top-k batch compiles decode once, its greedy slots
    bitwise-match a solo all-greedy run, and a fixed per-request seed
    reproduces the sampled stream regardless of rid / slot placement."""
    cfg, params = gemma
    rng = np.random.RandomState(4)
    prompts = jnp.asarray(rng.randint(0, cfg.vocab_size, (3, 12)),
                          jnp.int32)
    eng = ServeEngine(cfg, params, max_seq=96)
    mixed = eng.generate(prompts, max_new=6,
                         temperature=[0.0, 0.8, 0.0], top_k=[0, 4, 0])
    assert eng.decode_compilations == 1
    solo = ServeEngine(cfg, params, max_seq=96).generate(
        prompts, max_new=6, temperature=0.0)
    got, ref = np.asarray(mixed.tokens), np.asarray(solo.tokens)
    np.testing.assert_array_equal(got[0], ref[0])
    np.testing.assert_array_equal(got[2], ref[2])
    # sampled tokens stay in-vocab
    assert int(np.max(got[1])) < cfg.vocab_size
    # per-request seed → reproducible sampling, independent of rid
    serve = dataclasses.replace(cfg.serve, max_batch=2, max_seq=96)
    prompt = np.asarray(prompts[0])
    r1 = SlotScheduler(cfg, params, serve=serve).run(
        [Request(rid=0, tokens=prompt, max_new=5, temperature=0.9,
                 seed=7)])[0]
    r2 = SlotScheduler(cfg, params, serve=serve).run(
        [Request(rid=99, tokens=prompt, max_new=5, temperature=0.9,
                 seed=7)])[0]
    np.testing.assert_array_equal(r1.tokens, r2.tokens)


def test_mixed_family_stream_single_compilation():
    """One engine per family, mixed greedy/sampled requests in the same
    stream: the decode chunk still compiles exactly once per engine for
    recurrent families too (the acceptance-criteria contract)."""
    cfg = reduced_config("xlstm-1.3b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    serve = dataclasses.replace(cfg.serve, max_batch=2, max_seq=48,
                                decode_chunk=4)
    sched = SlotScheduler(cfg, params, serve=serve)
    rng = np.random.RandomState(5)
    reqs = [Request(rid=i,
                    tokens=rng.randint(0, cfg.vocab_size, (n,)).astype(
                        np.int32),
                    max_new=3,
                    temperature=0.8 if i % 2 else 0.0,
                    top_k=4 if i % 2 else 0, seed=i)
            for i, n in enumerate([6, 9, 4, 11])]
    done = {c.rid: c for c in sched.run(list(reqs))}
    assert len(done) == len(reqs)
    assert sched.decode_compilations == 1
    # greedy requests still match the oracle in the mixed stream
    for r in reqs:
        if r.temperature == 0.0:
            ref = _oracle_continuation(cfg, params, r.tokens, r.max_new)
            np.testing.assert_array_equal(done[r.rid].tokens, ref)


def test_param_swap_invalidates_schedulers(gemma):
    """Swapping engine.params (checkpoint load) must rebuild schedulers:
    the old ones closed over stale weights and cached stale prefix KV."""
    cfg, _ = gemma
    p1 = M.init_params(jax.random.PRNGKey(10), cfg)
    p2 = M.init_params(jax.random.PRNGKey(11), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(4), (2, 12), 0,
                                 cfg.vocab_size)
    engine = ServeEngine(cfg, p1, max_seq=64)
    engine.generate(prompts, max_new=4)
    engine.params = p2
    swapped = engine.generate(prompts, max_new=4).tokens
    fresh = ServeEngine(cfg, p2, max_seq=64).generate(
        prompts, max_new=4).tokens
    np.testing.assert_array_equal(np.asarray(swapped), np.asarray(fresh))


def test_generate_temperature_without_key(gemma):
    """Regression: temperature > 0 with key=None used to crash in
    jax.random.split(None); per-slot keys must fall back to seeded
    derivation.  An explicit key must be honored AND deterministic:
    the same key reproduces the same sampled tokens across calls (keys
    fold in the batch row, not the ever-growing engine rid)."""
    cfg, params = gemma
    engine = ServeEngine(cfg, params, max_seq=64)
    prompts = jnp.ones((2, 8), jnp.int32)
    res = engine.generate(prompts, max_new=4, temperature=0.7)
    assert res.tokens.shape == (2, 4)
    assert int(res.tokens.max()) < cfg.vocab_size
    k = jax.random.PRNGKey(3)
    res2 = engine.generate(prompts, max_new=4, temperature=0.7, key=k)
    res3 = engine.generate(prompts, max_new=4, temperature=0.7, key=k)
    np.testing.assert_array_equal(np.asarray(res2.tokens),
                                  np.asarray(res3.tokens))


def test_recurrent_engine_no_temperature_crash():
    cfg = reduced_config("xlstm-1.3b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, max_seq=32)
    res = engine.generate(jnp.ones((2, 6), jnp.int32), max_new=3,
                          temperature=0.9)
    assert res.tokens.shape == (2, 3)
    assert engine.decode_compilations == 1


def test_countmin_decay_ages_counts():
    """decay() halves count-min estimates (floored to keep integer-count
    semantics) and preserves the one-sided overestimate."""
    sk = csvec.csvec_zeros(1 << 16, cols=64, rows=4, signed=False)
    idx = np.arange(10, dtype=np.int32)
    for _ in range(4):
        sk = csvec.accumulate_coords(sk, idx, np.ones(10, np.float32))
    before = np.asarray(csvec.query(sk, idx))
    assert (before >= 4).all()          # overestimate: never undercounts
    aged = csvec.decay(sk, 0.5)
    after = np.asarray(csvec.query(aged, idx))
    assert (after <= before // 2 + 1).all() and (after >= 2).all()
    # a once-seen coordinate decays to exactly zero, not dust
    one = csvec.accumulate_coords(
        csvec.csvec_zeros(1 << 16, cols=64, rows=4, signed=False),
        np.array([7], np.int32), np.ones(1, np.float32))
    for _ in range(2):
        one = csvec.decay(one, 0.5)
    assert float(csvec.query(one, np.array([7], np.int32))[0]) == 0.0


@pytest.mark.parametrize("arch", ["gemma-2b", "xlstm-1.3b"])
def test_serve_state_pspecs(arch):
    """Slot-state decode specs: kv leaves split-KV over model on the seq
    axis (attention) / recurrent leaves per cache_pspecs, per-slot
    bookkeeping and sampling state on the batch axis."""
    from jax.sharding import PartitionSpec as P

    from repro.launch.shardings import serve_state_pspecs
    from repro.models.sharding import decode_rules

    cfg = reduced_config(arch)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    serve = dataclasses.replace(cfg.serve, max_batch=2, max_seq=64)
    sched = SlotScheduler(cfg, params, serve=serve)
    rules = decode_rules(multi_pod=False, long_context=False)
    specs = serve_state_pspecs(cfg, sched.state, rules)
    b = rules["batch"]
    if arch == "gemma-2b":
        assert specs.cache["kv"]["k"] == P(None, b, "model", None, None)
    else:
        assert specs.cache["mlstm"]["C"][1] == b
    assert specs.pos == P(b)
    assert specs.temp == P(b)
    assert specs.top_k == P(b)
    assert specs.keys == P(b, None)


def test_rtpm_nan_safe_selection():
    """A NaN/inf candidate can no longer hijack best-of-inits selection."""
    from repro.cpd.rtpm import _nan_safe_argmax
    vals = jnp.array([1.0, jnp.nan, 3.0, jnp.inf, 2.0])
    assert int(_nan_safe_argmax(vals)) == 2
    assert int(_nan_safe_argmax(jnp.array([jnp.nan, jnp.nan]))) == 0
