"""Continuous-batching engine: slot-cache decode correctness against
per-request full-context recompute (all families), chunked prefill,
per-request sampling, single decode compilation for mixed request
streams, and count-min gated prefix caching."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import reduced_config
from repro.models import layers as ly
from repro.models import model as M
from repro.models import transformer as tf
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import Request, SlotScheduler
from repro.sketch import csvec


@pytest.fixture(scope="module")
def gemma():
    cfg = reduced_config("gemma-2b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _oracle_continuation(cfg, params, prompt: np.ndarray, n: int):
    """Teacher-forced greedy continuation via full-context recompute."""
    seq = jnp.asarray(prompt, jnp.int32)[None, :]
    out = []
    for _ in range(n):
        y, _, _ = tf.forward(params, tf.embed_inputs(
            params, {"tokens": seq}, cfg), cfg, mode="train")
        lg = ly.logits_fn(params, y[:, -1:], cfg)[:, 0, :cfg.vocab_size]
        nxt = int(jnp.argmax(lg, axis=-1)[0])
        out.append(nxt)
        seq = jnp.concatenate(
            [seq, jnp.full((1, 1), nxt, jnp.int32)], axis=1)
    return out


def test_mixed_length_stream_matches_recompute_and_compiles_once(gemma):
    """The tentpole contract: a stream of mixed-length, mixed-budget
    requests through the chunk-prefilled slot cache decodes
    token-for-token identically to per-request full-context recompute,
    while decode AND chunked prefill each compile exactly once."""
    cfg, params = gemma
    serve = dataclasses.replace(cfg.serve, max_batch=3, max_seq=96,
                                decode_chunk=4, prefill_bucket=16)
    sched = SlotScheduler(cfg, params, serve=serve)
    rng = np.random.RandomState(0)
    lens = [5, 16, 9, 23, 31, 12]
    reqs = [Request(rid=i,
                    tokens=rng.randint(0, cfg.vocab_size, (n,)).astype(
                        np.int32),
                    max_new=3 + i % 3)
            for i, n in enumerate(lens)]
    done = {c.rid: c for c in sched.run(list(reqs))}
    assert len(done) == len(reqs)
    for r in reqs:
        ref = _oracle_continuation(cfg, params, r.tokens, r.max_new)
        np.testing.assert_array_equal(done[r.rid].tokens, ref,
                                      err_msg=f"rid {r.rid}")
    assert sched.decode_compilations == 1
    assert sched.prefill_compilations == 1


@pytest.mark.parametrize("arch", ["xlstm-1.3b", "zamba2-2.7b"])
def test_recurrent_slot_stream_matches_recompute(arch):
    """ssm / hybrid requests ride the slot scheduler (no synchronized
    fallback): mixed-length streams — including a 1-token prompt, which
    exercises the zero-state slot reset — match full-context recompute
    token-for-token, with one decode compilation."""
    cfg = reduced_config(arch)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    serve = dataclasses.replace(cfg.serve, max_batch=2, max_seq=48,
                                decode_chunk=4)
    sched = SlotScheduler(cfg, params, serve=serve)
    rng = np.random.RandomState(0)
    reqs = [Request(rid=i,
                    tokens=rng.randint(0, cfg.vocab_size, (n,)).astype(
                        np.int32),
                    max_new=3)
            for i, n in enumerate([6, 11, 1, 9])]
    done = {c.rid: c for c in sched.run(list(reqs))}
    for r in reqs:
        ref = _oracle_continuation(cfg, params, r.tokens, r.max_new)
        np.testing.assert_array_equal(done[r.rid].tokens, ref,
                                      err_msg=f"{arch} rid {r.rid}")
    assert sched.decode_compilations == 1


def test_chunked_prefill_hit_matches_miss_multi_bucket(gemma):
    """A cached-prefix hit whose uncached suffix spans MULTIPLE prefill
    buckets is chunk-prefilled against the slot cache and reproduces the
    cold-miss output token-for-token; decode and prefill each stay at one
    compilation."""
    cfg, params = gemma
    serve = dataclasses.replace(cfg.serve, max_batch=2, max_seq=96,
                                prefill_bucket=8, prefix_block=16,
                                admit_threshold=2)
    sched = SlotScheduler(cfg, params, serve=serve)
    rng = np.random.RandomState(1)
    prompt = np.concatenate([
        rng.randint(0, cfg.vocab_size, (16,)).astype(np.int32),   # prefix
        rng.randint(0, cfg.vocab_size, (20,)).astype(np.int32)])  # suffix
    assert len(prompt) - 16 > serve.prefill_bucket   # suffix > 1 bucket
    outs = []
    for i in range(4):
        done = sched.run([Request(rid=i, tokens=prompt, max_new=5)])
        outs.append(done[0])
    st = sched.prefix_cache.stats
    assert st.admitted >= 1 and st.hits >= 1
    assert outs[-1].prefix_hit and not outs[0].prefix_hit
    for o in outs[1:]:
        np.testing.assert_array_equal(o.tokens, outs[0].tokens)
    np.testing.assert_array_equal(
        outs[0].tokens, _oracle_continuation(cfg, params, prompt, 5))
    assert sched.decode_compilations == 1
    assert sched.prefill_compilations == 1


def test_prefix_cache_respects_byte_budget(gemma):
    """LRU eviction keeps cache-held pool-block bytes at or under the
    configured budget no matter how many prefixes qualify for admission,
    and the refcount books balance: once every slot has retired, the only
    reserved pool blocks are the ones the prefix cache holds."""
    cfg, params = gemma
    serve = dataclasses.replace(cfg.serve, max_batch=2, max_seq=96,
                                prefill_bucket=16, prefix_block=16,
                                kv_block_size=16, admit_threshold=1,
                                prefix_cache_bytes=6 * 1024)
    sched = SlotScheduler(cfg, params, serve=serve)
    rng = np.random.RandomState(2)
    for i in range(6):
        prompt = rng.randint(0, cfg.vocab_size, (16,)).astype(np.int32)
        sched.run([Request(rid=i, tokens=prompt, max_new=2)])
    st = sched.prefix_cache.stats
    assert st.admitted >= 2
    assert st.evicted >= 1
    assert st.bytes <= serve.prefix_cache_bytes
    # byte counter == unique held blocks, and allocator agrees: with all
    # slots retired, reserved pool blocks are exactly the cache's holds
    held = sched.prefix_cache.held_blocks()
    assert st.bytes == held * sched.alloc.block_bytes
    assert sched.alloc.reserved == held
    assert sched.alloc.free_count == sched.num_blocks - held


def test_exact_length_prefill_still_hits(gemma):
    """prefill_bucket=1 (exact-length chunks, the documented moe setting)
    must not disable prefix-cache hits — chunked prefill degenerates to
    token-by-token but the hit path still works."""
    cfg, params = gemma
    serve = dataclasses.replace(cfg.serve, max_batch=2, max_seq=96,
                                prefill_bucket=1, prefix_block=8,
                                kv_block_size=8, admit_threshold=2)
    sched = SlotScheduler(cfg, params, serve=serve)
    rng = np.random.RandomState(3)
    prompt = rng.randint(0, cfg.vocab_size, (13,)).astype(np.int32)
    outs = [sched.run([Request(rid=i, tokens=prompt, max_new=4)])[0]
            for i in range(4)]
    assert sched.prefix_cache.stats.hits >= 1
    assert outs[-1].prefix_hit
    for o in outs[1:]:
        np.testing.assert_array_equal(o.tokens, outs[0].tokens)
    np.testing.assert_array_equal(
        outs[0].tokens, _oracle_continuation(cfg, params, prompt, 4))


def test_mixed_per_request_sampling_one_compilation(gemma):
    """Greedy and sampled requests share one compiled chunk: a mixed
    temperature/top-k batch compiles decode once, its greedy slots
    bitwise-match a solo all-greedy run, and a fixed per-request seed
    reproduces the sampled stream regardless of rid / slot placement."""
    cfg, params = gemma
    rng = np.random.RandomState(4)
    prompts = jnp.asarray(rng.randint(0, cfg.vocab_size, (3, 12)),
                          jnp.int32)
    eng = ServeEngine(cfg, params, max_seq=96)
    mixed = eng.generate(prompts, max_new=6,
                         temperature=[0.0, 0.8, 0.0], top_k=[0, 4, 0])
    assert eng.decode_compilations == 1
    solo = ServeEngine(cfg, params, max_seq=96).generate(
        prompts, max_new=6, temperature=0.0)
    got, ref = np.asarray(mixed.tokens), np.asarray(solo.tokens)
    np.testing.assert_array_equal(got[0], ref[0])
    np.testing.assert_array_equal(got[2], ref[2])
    # sampled tokens stay in-vocab
    assert int(np.max(got[1])) < cfg.vocab_size
    # per-request seed → reproducible sampling, independent of rid
    serve = dataclasses.replace(cfg.serve, max_batch=2, max_seq=96)
    prompt = np.asarray(prompts[0])
    r1 = SlotScheduler(cfg, params, serve=serve).run(
        [Request(rid=0, tokens=prompt, max_new=5, temperature=0.9,
                 seed=7)])[0]
    r2 = SlotScheduler(cfg, params, serve=serve).run(
        [Request(rid=99, tokens=prompt, max_new=5, temperature=0.9,
                 seed=7)])[0]
    np.testing.assert_array_equal(r1.tokens, r2.tokens)


def test_mixed_family_stream_single_compilation():
    """One engine per family, mixed greedy/sampled requests in the same
    stream: the decode chunk still compiles exactly once per engine for
    recurrent families too (the acceptance-criteria contract)."""
    cfg = reduced_config("xlstm-1.3b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    serve = dataclasses.replace(cfg.serve, max_batch=2, max_seq=48,
                                decode_chunk=4)
    sched = SlotScheduler(cfg, params, serve=serve)
    rng = np.random.RandomState(5)
    reqs = [Request(rid=i,
                    tokens=rng.randint(0, cfg.vocab_size, (n,)).astype(
                        np.int32),
                    max_new=3,
                    temperature=0.8 if i % 2 else 0.0,
                    top_k=4 if i % 2 else 0, seed=i)
            for i, n in enumerate([6, 9, 4, 11])]
    done = {c.rid: c for c in sched.run(list(reqs))}
    assert len(done) == len(reqs)
    assert sched.decode_compilations == 1
    # greedy requests still match the oracle in the mixed stream
    for r in reqs:
        if r.temperature == 0.0:
            ref = _oracle_continuation(cfg, params, r.tokens, r.max_new)
            np.testing.assert_array_equal(done[r.rid].tokens, ref)


def test_param_swap_invalidates_schedulers(gemma):
    """Swapping engine.params (checkpoint load) must rebuild schedulers:
    the old ones closed over stale weights and cached stale prefix KV."""
    cfg, _ = gemma
    p1 = M.init_params(jax.random.PRNGKey(10), cfg)
    p2 = M.init_params(jax.random.PRNGKey(11), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(4), (2, 12), 0,
                                 cfg.vocab_size)
    engine = ServeEngine(cfg, p1, max_seq=64)
    engine.generate(prompts, max_new=4)
    engine.params = p2
    swapped = engine.generate(prompts, max_new=4).tokens
    fresh = ServeEngine(cfg, p2, max_seq=64).generate(
        prompts, max_new=4).tokens
    np.testing.assert_array_equal(np.asarray(swapped), np.asarray(fresh))


def test_generate_temperature_without_key(gemma):
    """Regression: temperature > 0 with key=None used to crash in
    jax.random.split(None); per-slot keys must fall back to seeded
    derivation.  An explicit key must be honored AND deterministic:
    the same key reproduces the same sampled tokens across calls (keys
    fold in the batch row, not the ever-growing engine rid)."""
    cfg, params = gemma
    engine = ServeEngine(cfg, params, max_seq=64)
    prompts = jnp.ones((2, 8), jnp.int32)
    res = engine.generate(prompts, max_new=4, temperature=0.7)
    assert res.tokens.shape == (2, 4)
    assert int(res.tokens.max()) < cfg.vocab_size
    k = jax.random.PRNGKey(3)
    res2 = engine.generate(prompts, max_new=4, temperature=0.7, key=k)
    res3 = engine.generate(prompts, max_new=4, temperature=0.7, key=k)
    np.testing.assert_array_equal(np.asarray(res2.tokens),
                                  np.asarray(res3.tokens))


def test_recurrent_engine_no_temperature_crash():
    cfg = reduced_config("xlstm-1.3b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, max_seq=32)
    res = engine.generate(jnp.ones((2, 6), jnp.int32), max_new=3,
                          temperature=0.9)
    assert res.tokens.shape == (2, 3)
    assert engine.decode_compilations == 1


def test_countmin_decay_ages_counts():
    """decay() halves count-min estimates (floored to keep integer-count
    semantics) and preserves the one-sided overestimate."""
    sk = csvec.csvec_zeros(1 << 16, cols=64, rows=4, signed=False)
    idx = np.arange(10, dtype=np.int32)
    for _ in range(4):
        sk = csvec.accumulate_coords(sk, idx, np.ones(10, np.float32))
    before = np.asarray(csvec.query(sk, idx))
    assert (before >= 4).all()          # overestimate: never undercounts
    aged = csvec.decay(sk, 0.5)
    after = np.asarray(csvec.query(aged, idx))
    assert (after <= before // 2 + 1).all() and (after >= 2).all()
    # a once-seen coordinate decays to exactly zero, not dust
    one = csvec.accumulate_coords(
        csvec.csvec_zeros(1 << 16, cols=64, rows=4, signed=False),
        np.array([7], np.int32), np.ones(1, np.float32))
    for _ in range(2):
        one = csvec.decay(one, 0.5)
    assert float(csvec.query(one, np.array([7], np.int32))[0]) == 0.0


@pytest.mark.parametrize("arch", ["gemma-2b", "xlstm-1.3b"])
def test_serve_state_pspecs(arch):
    """Slot-state decode specs: the paged KV pool's block axis takes the
    split-KV role over model (blocks are interchangeable) with block
    tables replicated; recurrent leaves per cache_pspecs; per-slot
    bookkeeping and sampling state on the batch axis."""
    from jax.sharding import PartitionSpec as P

    from repro.launch.shardings import serve_state_pspecs
    from repro.models.sharding import decode_rules

    cfg = reduced_config(arch)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    serve = dataclasses.replace(cfg.serve, max_batch=2, max_seq=64)
    sched = SlotScheduler(cfg, params, serve=serve)
    rules = decode_rules(multi_pod=False, long_context=False)
    specs = serve_state_pspecs(cfg, sched.state, rules)
    b = rules["batch"]
    if arch == "gemma-2b":
        # pool (L, NB, bs, K, hd): block axis split-KV over model
        assert specs.cache["kv"]["k"] == P(None, "model", None, None, None)
        assert specs.tables == P(None, None)
    else:
        assert specs.cache["mlstm"]["C"][1] == b
        assert specs.tables == P(b, None)
    assert specs.pos == P(b)
    assert specs.temp == P(b)
    assert specs.top_k == P(b)
    assert specs.keys == P(b, None)


def test_paged_pool_reserves_blocks_not_max_seq(gemma):
    """The paged-KV contract: a request reserves ceil((S + max_new) /
    kv_block_size) pool blocks, not max_seq dense rows — short requests
    in a big-max_seq engine cut reserved KV bytes by >= 4x — and every
    block returns to the free list once its slot retires."""
    cfg, params = gemma
    serve = dataclasses.replace(cfg.serve, max_batch=4, max_seq=256,
                                prefill_bucket=16, kv_block_size=16,
                                admit_threshold=100)   # no admission noise
    sched = SlotScheduler(cfg, params, serve=serve)
    rng = np.random.RandomState(6)
    reqs = [Request(rid=i,
                    tokens=rng.randint(0, cfg.vocab_size,
                                       (12 + i,)).astype(np.int32),
                    max_new=4)
            for i in range(8)]
    done = sched.run(list(reqs))
    assert len(done) == 8
    assert sched.kv_peak_reserved_bytes() * 4 <= sched.kv_dense_equiv_bytes()
    assert sched.alloc.reserved == 0          # all blocks back on the list
    assert sched.alloc.free_count == sched.num_blocks
    assert sched.decode_compilations == 1


def test_pool_pressure_defers_admission(gemma):
    """A pool smaller than max_batch's worth of requests must defer
    admissions until retirements free blocks — never corrupt KV or drop
    requests: everything completes and matches the oracle."""
    cfg, params = gemma
    serve = dataclasses.replace(cfg.serve, max_batch=4, max_seq=64,
                                prefill_bucket=16, kv_block_size=16,
                                num_kv_blocks=4, admit_threshold=100)
    sched = SlotScheduler(cfg, params, serve=serve)
    rng = np.random.RandomState(7)
    # each request needs 2 of the 4 pool blocks: at most 2 in flight
    reqs = [Request(rid=i,
                    tokens=rng.randint(0, cfg.vocab_size,
                                       (20,)).astype(np.int32),
                    max_new=4)
            for i in range(4)]
    done = {c.rid: c for c in sched.run(list(reqs))}
    assert len(done) == 4
    for r in reqs:
        ref = _oracle_continuation(cfg, params, r.tokens, r.max_new)
        np.testing.assert_array_equal(done[r.rid].tokens, ref,
                                      err_msg=f"rid {r.rid}")
    assert sched.alloc.peak_reserved <= 4
    assert sched.alloc.reserved == 0
    assert sched.decode_compilations == 1
    # a request the pool can NEVER serve is rejected at submit time —
    # not left to head-of-line-block the queue and crash mid-stream
    small = dataclasses.replace(serve, num_kv_blocks=2)
    s2 = SlotScheduler(cfg, params, serve=small)
    with pytest.raises(AssertionError, match="KV blocks"):
        s2.submit(Request(rid=99,
                          tokens=rng.randint(0, cfg.vocab_size,
                                             (36,)).astype(np.int32),
                          max_new=4))                # 3 blocks > pool 2


def test_deferred_admission_counts_request_once(gemma):
    """A request stuck behind pool pressure is retried every scheduler
    round, but must feed the count-min tracker (and lookup stats) exactly
    ONCE — otherwise a one-shot prompt accrues one count per retry,
    spuriously crosses admit_threshold, and a cold prefix evicts real
    heavy hitters."""
    cfg, params = gemma
    serve = dataclasses.replace(cfg.serve, max_batch=2, max_seq=64,
                                prefill_bucket=16, prefix_block=16,
                                kv_block_size=16, num_kv_blocks=3,
                                admit_threshold=2)
    sched = SlotScheduler(cfg, params, serve=serve)
    rng = np.random.RandomState(13)
    # A occupies 2 of 3 blocks for 2 decode chunks; B (2 blocks) must wait
    a = Request(rid=0, tokens=rng.randint(0, cfg.vocab_size,
                                          (20,)).astype(np.int32),
                max_new=12)
    b = Request(rid=1, tokens=rng.randint(0, cfg.vocab_size,
                                          (20,)).astype(np.int32),
                max_new=4)
    done = sched.run([a, b])
    assert len(done) == 2
    st = sched.prefix_cache.stats
    assert st.lookups == 2, "retries re-counted lookups"
    # B was observed once: its 16-token prefix has count 1 < threshold 2,
    # so nothing may have been admitted off the back of retry inflation
    assert len(sched.prefix_cache) == 0
    assert st.admitted == 0


def test_pool_pressure_never_wipes_busy_entries(gemma):
    """Pool-pressure eviction must stop at entries whose blocks live
    slots still reference: removing them frees nothing (the blocks stay
    reserved), so a transient spike must not wipe hot cached prefixes."""
    cfg, params = gemma
    serve = dataclasses.replace(cfg.serve, max_batch=2, max_seq=64,
                                prefill_bucket=16, prefix_block=16,
                                kv_block_size=16, num_kv_blocks=4,
                                admit_threshold=1)
    sched = SlotScheduler(cfg, params, serve=serve)
    rng = np.random.RandomState(14)
    pre = rng.randint(0, cfg.vocab_size, (16,)).astype(np.int32)
    # admit the prefix (threshold 1), slot retires, cache holds 1 block
    sched.run([Request(rid=0, tokens=pre, max_new=4)])
    assert len(sched.prefix_cache) == 1
    # B shares the cached block and holds the pool for 2 decode chunks;
    # C (2 blocks, 1 free) must defer — and must NOT evict B's busy entry
    b = Request(rid=1,
                tokens=np.concatenate(
                    [pre, rng.randint(0, cfg.vocab_size,
                                      (16,)).astype(np.int32)]),
                max_new=12)
    c = Request(rid=2, tokens=rng.randint(0, cfg.vocab_size,
                                          (16,)).astype(np.int32),
                max_new=4)
    done = {x.rid: x for x in sched.run([b, c])}
    assert len(done) == 2 and done[1].prefix_hit
    assert tuple(int(t) for t in pre) in sched.prefix_cache._entries, (
        "pool pressure wiped a busy (still-referenced) cache entry")
    for r in (b, c):
        np.testing.assert_array_equal(
            done[r.rid].tokens,
            _oracle_continuation(cfg, params, r.tokens, r.max_new))


def test_prefix_hit_is_zero_copy(gemma):
    """A prefix-cache hit installs the cached entry's PHYSICAL block ids
    into the slot's table (no KV rows move) and bumps their refcount;
    slot retirement releases the reference, the cache keeps its own."""
    cfg, params = gemma
    serve = dataclasses.replace(cfg.serve, max_batch=2, max_seq=96,
                                prefill_bucket=16, prefix_block=16,
                                kv_block_size=16, admit_threshold=2)
    sched = SlotScheduler(cfg, params, serve=serve)
    rng = np.random.RandomState(8)
    prompt = rng.randint(0, cfg.vocab_size, (32,)).astype(np.int32)
    for i in range(2):
        sched.run([Request(rid=i, tokens=prompt, max_new=4)])
    # threshold 2: the LONGEST qualifying prefix (the full 32-token
    # prompt, 2 blocks) is admitted on the second observation
    key = tuple(int(t) for t in prompt)
    ids = sched.prefix_cache._entries[key].block_ids
    assert len(ids) == 2
    assert int(sched.alloc.rc[ids[0]]) == 1           # cache hold only
    # third request hits; keep it in flight to observe the shared ref
    sched.submit(Request(rid=2, tokens=prompt, max_new=12))
    done = sched.step()                               # decode_chunk=8 < 12
    assert not done and sched._slot_hit[0]
    assert sched._slot_blocks[0][0] == ids[0]         # shared by reference
    assert int(sched.alloc.rc[ids[0]]) == 2           # cache + slot
    out = sched.run()
    assert len(out) == 1 and out[0].prefix_hit
    assert int(sched.alloc.rc[ids[0]]) == 1           # slot ref released


def test_hit_extends_cached_prefix(gemma):
    """Regression (hot prompt starved of its long prefix): hits feed the
    admission path too, so a prompt that keeps hitting a short cached
    prefix eventually gets its LONGEST block-multiple prefix admitted and
    served — with outputs bitwise-stable throughout."""
    cfg, params = gemma
    serve = dataclasses.replace(cfg.serve, max_batch=2, max_seq=96,
                                prefill_bucket=16, prefix_block=16,
                                kv_block_size=16, admit_threshold=2)
    sched = SlotScheduler(cfg, params, serve=serve)
    rng = np.random.RandomState(9)
    pre = rng.randint(0, cfg.vocab_size, (16,)).astype(np.int32)
    # two different-tailed prompts get the SHORT 16-token prefix admitted
    for i in range(2):
        tail = rng.randint(0, cfg.vocab_size, (8,)).astype(np.int32)
        sched.run([Request(rid=i, tokens=np.concatenate([pre, tail]),
                           max_new=3)])
    assert tuple(int(t) for t in pre) in sched.prefix_cache._entries
    # now a hot prompt whose longest prefix (its full 48 tokens) only
    # accrues count-min frequency through HITS on the short prefix
    prompt = np.concatenate([pre, rng.randint(0, cfg.vocab_size,
                                              (32,)).astype(np.int32)])
    outs = [sched.run([Request(rid=10 + i, tokens=prompt, max_new=4)])[0]
            for i in range(4)]
    assert outs[1].prefix_hit
    long_key = tuple(int(t) for t in prompt)          # 48 = 3 blocks
    assert long_key in sched.prefix_cache._entries, (
        "hit path never extended the cached prefix")
    # the last run serves the full-prompt prefix (plen == S: decode
    # resumes inside a shared block — idempotent rewrite) bitwise-equal
    for o in outs[1:]:
        np.testing.assert_array_equal(o.tokens, outs[0].tokens)
    np.testing.assert_array_equal(
        outs[0].tokens, _oracle_continuation(cfg, params, prompt, 4))


def test_chunk_prefill_hit_matches_miss_nondividing_max_seq(gemma):
    """Regression for the tail clamp: with prefill_bucket not dividing
    max_seq, chunk starts must stay absolute bucket multiples on both the
    cold-miss and the cached-prefix-hit paths (the old clamp shifted the
    tail chunk to max_seq - bucket), keeping hit == miss bitwise."""
    cfg, params = gemma
    serve = dataclasses.replace(cfg.serve, max_batch=2, max_seq=88,
                                prefill_bucket=32, prefix_block=16,
                                kv_block_size=16, admit_threshold=2)
    assert serve.max_seq % serve.prefill_bucket != 0
    sched = SlotScheduler(cfg, params, serve=serve)
    rng = np.random.RandomState(11)
    # prompt reaches into the non-dividing tail: S=80 > max_seq - bucket
    prompt = rng.randint(0, cfg.vocab_size, (80,)).astype(np.int32)
    outs = [sched.run([Request(rid=i, tokens=prompt, max_new=6)])[0]
            for i in range(4)]
    assert outs[-1].prefix_hit and not outs[0].prefix_hit
    for o in outs[1:]:
        np.testing.assert_array_equal(o.tokens, outs[0].tokens)
    np.testing.assert_array_equal(
        outs[0].tokens, _oracle_continuation(cfg, params, prompt, 6))
    assert sched.decode_compilations == 1
    assert sched.prefill_compilations == 1


def test_prefix_cache_lru_refresh_and_rejected_stats():
    """Satellite regressions on SketchPrefixCache bookkeeping: (a)
    re-admitting a present key refreshes LRU recency (the old early
    return left eviction order stale); (b) observe() counts a prompt
    whose longest qualifying prefix is already cached in stats.rejected
    instead of silently returning None."""
    import dataclasses as dc

    from repro.configs.base import ServeConfig
    from repro.serve.prefix_cache import SketchPrefixCache
    from repro.serve.scheduler import BlockAllocator

    sv = dc.replace(ServeConfig(), prefix_block=4, admit_threshold=1,
                    prefix_cache_bytes=128)            # 2 x 64-byte blocks
    alloc = BlockAllocator(num_blocks=8, block_bytes=64)
    cache = SketchPrefixCache(sv, allocator=alloc, block_size=4)
    a = np.arange(0, 4, dtype=np.int32)
    b = np.arange(4, 8, dtype=np.int32)
    c = np.arange(8, 12, dtype=np.int32)
    ids = {}
    for name, toks in (("a", a), ("b", b)):
        blk = alloc.alloc(1)
        cache.admit(toks, 4, tuple(blk))
        alloc.unref(blk)                               # "slot" retires
        ids[name] = blk[0]
    cache.admit(a, 4, (ids["a"],))                     # refresh, not no-op
    blk = alloc.alloc(1)
    cache.admit(c, 4, tuple(blk))                      # over budget: evict
    alloc.unref(blk)
    assert cache.stats.evicted == 1
    assert cache.lookup(a) is not None, "refreshed entry was evicted"
    assert cache.lookup(b) is None, "stale-LRU entry survived"
    # rc books: evicted b's block went back to the free list
    assert int(alloc.rc[ids["b"]]) == 0
    # (b): longest qualifying prefix cached -> rejected must count it
    rej0 = cache.stats.rejected
    assert cache.observe(a) is None
    assert cache.stats.rejected == rej0 + 1


def test_reseed_only_affects_unadmitted(gemma):
    """SlotScheduler.reseed(): in-flight slots keep the sampling keys
    they were admitted with (per-slot keys are engine state resolved at
    admission); requests admitted AFTER a reseed derive from the new base
    key, reproducibly across schedulers."""
    cfg, params = gemma
    serve = dataclasses.replace(cfg.serve, max_batch=2, max_seq=64,
                                decode_chunk=2)
    rng = np.random.RandomState(12)
    prompt = rng.randint(0, cfg.vocab_size, (8,)).astype(np.int32)

    def mk(rid):
        return Request(rid=rid, tokens=prompt.copy(), max_new=6,
                       temperature=0.9)                # base-key derived

    ctrl = SlotScheduler(cfg, params, serve=serve).run([mk(0)])[0]
    s2 = SlotScheduler(cfg, params, serve=serve)
    s2.submit(mk(0))
    done = s2.step()                                   # in flight (2 of 6)
    assert not done
    s2.reseed(jax.random.PRNGKey(999))                 # mid-flight reseed
    out = s2.run()[0]
    np.testing.assert_array_equal(out.tokens, ctrl.tokens)
    # post-reseed requests are reproducible: same reseed key + same rid
    # on a fresh scheduler gives the same sampled stream
    s3 = SlotScheduler(cfg, params, serve=serve)
    s3.reseed(jax.random.PRNGKey(999))
    r2 = s2.run([mk(7)])[0]
    r3 = s3.run([mk(7)])[0]
    np.testing.assert_array_equal(r2.tokens, r3.tokens)


def test_rtpm_nan_safe_selection():
    """A NaN/inf candidate can no longer hijack best-of-inits selection."""
    from repro.cpd.rtpm import _nan_safe_argmax
    vals = jnp.array([1.0, jnp.nan, 3.0, jnp.inf, 2.0])
    assert int(_nan_safe_argmax(vals)) == 2
    assert int(_nan_safe_argmax(jnp.array([jnp.nan, jnp.nan]))) == 0


def _allocator_program(num_blocks: int, seed: int, steps: int) -> None:
    """Drive one random alloc/ref/unref/fork/cancel program against a
    BlockAllocator and assert its books after every operation:

      * conservation: reserved + free == num_blocks, always
      * no leaks: every block with refcount > 0 is reserved (off the
        free list), every refcount-0 block is ON the free list
      * no double-frees: the free list never holds duplicates
      * fork: the forked-from block keeps its other holders, the fork
        target is exclusively held
      * cancel: a mid-flight cancellation releases a "request's" whole
        block group in one bulk unref (the async front-end's cancel /
        expire / preempt path) — the books must balance immediately,
        with every other group's references untouched

    Every reference is tagged with the group ("request") that created
    it, so a cancel is a realistic storm primitive: groups die in random
    order, interleaved with allocs, shares and forks from survivors.
    """
    from repro.serve.scheduler import BlockAllocator

    rng = np.random.RandomState(seed)
    alloc = BlockAllocator(num_blocks, block_bytes=64)
    held: list = []            # (block, gid): one entry per reference
    next_gid = 0

    def check():
        assert alloc.reserved + alloc.free_count == alloc.num_blocks
        free = alloc._free
        assert len(set(free)) == len(free), "double-freed block"
        for b in range(alloc.num_blocks):
            rc = int(alloc.rc[b])
            assert rc >= 0
            assert (rc == 0) == (b in free), (b, rc)
        assert sorted(b for b, _ in held) == sorted(
            b for b in range(alloc.num_blocks)
            for _ in range(int(alloc.rc[b]))), "leaked or lost reference"

    for _ in range(steps):
        op = rng.randint(5)
        if op == 0:                                    # alloc (new group)
            n = int(rng.randint(1, 4))
            ids = alloc.alloc(n)
            if ids is None:
                assert n > alloc.free_count
            else:
                held.extend((b, next_gid) for b in ids)
                next_gid += 1
        elif op == 1 and held:                         # ref (share)
            b, g = held[rng.randint(len(held))]
            alloc.ref([b])
            held.append((b, g))
        elif op == 2 and held:                         # unref
            b, _ = held.pop(rng.randint(len(held)))
            alloc.unref([b])
        elif op == 3 and held:                         # fork (CoW)
            i = rng.randint(len(held))
            b, g = held[i]
            rc_before = int(alloc.rc[b])
            nb = alloc.fork(b)
            if nb is None:
                assert alloc.free_count == 0 and rc_before > 1
            else:
                held[i] = (nb, g)
                assert int(alloc.rc[nb]) >= 1
                if nb != b:
                    assert rc_before > 1
                    assert int(alloc.rc[b]) == rc_before - 1
                    assert int(alloc.rc[nb]) == 1
                else:
                    assert rc_before == 1
        elif op == 4 and held:                         # cancel one group
            gids = {g for _, g in held}
            victim = sorted(gids)[rng.randint(len(gids))]
            freed = [b for b, g in held if g == victim]
            held = [(b, g) for b, g in held if g != victim]
            alloc.unref(freed)                         # bulk, mid-flight
        check()
    # teardown as a full cancel storm: every surviving group goes down
    # in one bulk release each, in random order
    while held:
        gids = sorted({g for _, g in held})
        victim = gids[rng.randint(len(gids))]
        freed = [b for b, g in held if g == victim]
        held = [(b, g) for b, g in held if g != victim]
        alloc.unref(freed)
        check()
    assert alloc.reserved == 0 and alloc.free_count == num_blocks


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(num_blocks=st.integers(1, 12), seed=st.integers(0, 1 << 16),
           steps=st.integers(1, 120))
    def test_block_allocator_fuzz(num_blocks, seed, steps):
        _allocator_program(num_blocks, seed, steps)
except ImportError:
    # hypothesis isn't installed in this container: run the same property
    # over a deterministic grid of random programs instead
    @pytest.mark.parametrize("num_blocks,seed", [
        (1, 0), (2, 1), (3, 2), (4, 3), (6, 4), (8, 5), (12, 6), (5, 7)])
    def test_block_allocator_fuzz(num_blocks, seed):
        _allocator_program(num_blocks, seed, steps=120)
