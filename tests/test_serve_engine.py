"""Continuous-batching engine: slot-cache decode correctness against
per-request full-context recompute, single decode compilation for mixed
request streams, count-min gated prefix caching, and the sampling-key
regression."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import reduced_config
from repro.models import layers as ly
from repro.models import model as M
from repro.models import transformer as tf
from repro.serve.engine import ServeEngine
from repro.serve.prefix_cache import SketchPrefixCache
from repro.serve.scheduler import Request, SlotScheduler
from repro.sketch import csvec


@pytest.fixture(scope="module")
def gemma():
    cfg = reduced_config("gemma-2b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _oracle_continuation(cfg, params, prompt: np.ndarray, n: int):
    """Teacher-forced greedy continuation via full-context recompute."""
    seq = jnp.asarray(prompt, jnp.int32)[None, :]
    out = []
    for _ in range(n):
        y, _, _ = tf.forward(params, tf.embed_inputs(
            params, {"tokens": seq}, cfg), cfg, mode="train")
        lg = ly.logits_fn(params, y[:, -1:], cfg)[:, 0, :cfg.vocab_size]
        nxt = int(jnp.argmax(lg, axis=-1)[0])
        out.append(nxt)
        seq = jnp.concatenate(
            [seq, jnp.full((1, 1), nxt, jnp.int32)], axis=1)
    return out


def test_mixed_length_stream_matches_recompute_and_compiles_once(gemma):
    """The tentpole contract: a stream of mixed-length, mixed-budget
    requests through the padded/masked slot cache decodes token-for-token
    identically to per-request full-context recompute (this pins down what
    the old _grow_cache heuristic provided), while the decode step
    compiles exactly once (jit cache stats)."""
    cfg, params = gemma
    serve = dataclasses.replace(cfg.serve, max_batch=3, max_seq=96,
                                decode_chunk=4, prefill_bucket=16)
    sched = SlotScheduler(cfg, params, serve=serve)
    rng = np.random.RandomState(0)
    lens = [5, 16, 9, 23, 31, 12]
    reqs = [Request(rid=i,
                    tokens=rng.randint(0, cfg.vocab_size, (n,)).astype(
                        np.int32),
                    max_new=3 + i % 3)
            for i, n in enumerate(lens)]
    done = {c.rid: c for c in sched.run(list(reqs))}
    assert len(done) == len(reqs)
    for r in reqs:
        ref = _oracle_continuation(cfg, params, r.tokens, r.max_new)
        np.testing.assert_array_equal(done[r.rid].tokens, ref,
                                      err_msg=f"rid {r.rid}")
    assert sched.decode_compilations == 1


def test_prefix_cache_hit_path_matches_miss_path(gemma):
    """Count-min admission: a repeated prompt is admitted once its
    estimated frequency clears the threshold, later requests hit, and the
    hit path (cached KV + forced suffix decode) reproduces the miss path
    exactly.  Decode stays at one compilation throughout."""
    cfg, params = gemma
    serve = dataclasses.replace(cfg.serve, max_batch=2, max_seq=96,
                                prefill_bucket=16, prefix_block=16,
                                admit_threshold=2)
    sched = SlotScheduler(cfg, params, serve=serve)
    rng = np.random.RandomState(1)
    prompt = rng.randint(0, cfg.vocab_size, (21,)).astype(np.int32)
    outs = []
    for i in range(4):
        done = sched.run([Request(rid=i, tokens=prompt, max_new=5)])
        outs.append(done[0].tokens)
    st = sched.prefix_cache.stats
    assert st.admitted >= 1
    assert st.hits >= 1
    assert sched.run(
        [Request(rid=99, tokens=prompt, max_new=5)])[0].prefix_hit
    for o in outs[1:]:
        np.testing.assert_array_equal(o, outs[0])
    np.testing.assert_array_equal(
        outs[0], _oracle_continuation(cfg, params, prompt, 5))
    assert sched.decode_compilations == 1


def test_prefix_cache_respects_byte_budget(gemma):
    """LRU eviction keeps cached KV bytes at or under the configured
    budget no matter how many prefixes qualify for admission."""
    cfg, params = gemma
    serve = dataclasses.replace(cfg.serve, max_batch=2, max_seq=96,
                                prefill_bucket=16, prefix_block=16,
                                admit_threshold=1,
                                prefix_cache_bytes=6 * 1024)
    sched = SlotScheduler(cfg, params, serve=serve)
    rng = np.random.RandomState(2)
    for i in range(6):
        prompt = rng.randint(0, cfg.vocab_size, (16,)).astype(np.int32)
        sched.run([Request(rid=i, tokens=prompt, max_new=2)])
    st = sched.prefix_cache.stats
    assert st.admitted >= 2
    assert st.evicted >= 1
    assert st.bytes <= serve.prefix_cache_bytes
    # recompute from entries agrees with the running counter
    live = sum(e.nbytes for e in sched.prefix_cache._entries.values())
    assert live == st.bytes


def test_exact_length_prefill_still_hits(gemma):
    """prefill_bucket=1 (exact-length prefill, the documented moe setting)
    must not disable prefix-cache hits: the forced-suffix capacity is
    governed by prefix_block, not the prefill padding granularity."""
    cfg, params = gemma
    serve = dataclasses.replace(cfg.serve, max_batch=2, max_seq=96,
                                prefill_bucket=1, prefix_block=8,
                                admit_threshold=2)
    sched = SlotScheduler(cfg, params, serve=serve)
    rng = np.random.RandomState(3)
    prompt = rng.randint(0, cfg.vocab_size, (13,)).astype(np.int32)
    outs = [sched.run([Request(rid=i, tokens=prompt, max_new=4)])[0]
            for i in range(4)]
    assert sched.prefix_cache.stats.hits >= 1
    assert outs[-1].prefix_hit
    for o in outs[1:]:
        np.testing.assert_array_equal(o.tokens, outs[0].tokens)
    np.testing.assert_array_equal(
        outs[0].tokens, _oracle_continuation(cfg, params, prompt, 4))


def test_param_swap_invalidates_schedulers(gemma):
    """Swapping engine.params (checkpoint load) must rebuild schedulers:
    the old ones closed over stale weights and cached stale prefix KV."""
    cfg, _ = gemma
    p1 = M.init_params(jax.random.PRNGKey(10), cfg)
    p2 = M.init_params(jax.random.PRNGKey(11), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(4), (2, 12), 0,
                                 cfg.vocab_size)
    engine = ServeEngine(cfg, p1, max_seq=64)
    engine.generate(prompts, max_new=4)
    engine.params = p2
    swapped = engine.generate(prompts, max_new=4).tokens
    fresh = ServeEngine(cfg, p2, max_seq=64).generate(
        prompts, max_new=4).tokens
    np.testing.assert_array_equal(np.asarray(swapped), np.asarray(fresh))


def test_generate_temperature_without_key(gemma):
    """Regression: temperature > 0 with key=None used to crash in
    jax.random.split(None); it must fall back to a seeded PRNGKey."""
    cfg, params = gemma
    engine = ServeEngine(cfg, params, max_seq=64)
    prompts = jnp.ones((2, 8), jnp.int32)
    res = engine.generate(prompts, max_new=4, temperature=0.7)
    assert res.tokens.shape == (2, 4)
    assert int(res.tokens.max()) < cfg.vocab_size
    # and an explicit key is still honored
    res2 = engine.generate(prompts, max_new=4, temperature=0.7,
                           key=jax.random.PRNGKey(3))
    assert res2.tokens.shape == (2, 4)


def test_recurrent_fallback_no_temperature_crash():
    cfg = reduced_config("xlstm-1.3b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, max_seq=32)
    res = engine.generate(jnp.ones((2, 6), jnp.int32), max_new=3,
                          temperature=0.9)
    assert res.tokens.shape == (2, 3)


def test_countmin_decay_ages_counts():
    """decay() halves count-min estimates (floored to keep integer-count
    semantics) and preserves the one-sided overestimate."""
    sk = csvec.csvec_zeros(1 << 16, cols=64, rows=4, signed=False)
    idx = np.arange(10, dtype=np.int32)
    for _ in range(4):
        sk = csvec.accumulate_coords(sk, idx, np.ones(10, np.float32))
    before = np.asarray(csvec.query(sk, idx))
    assert (before >= 4).all()          # overestimate: never undercounts
    aged = csvec.decay(sk, 0.5)
    after = np.asarray(csvec.query(aged, idx))
    assert (after <= before // 2 + 1).all() and (after >= 2).all()
    # a once-seen coordinate decays to exactly zero, not dust
    one = csvec.accumulate_coords(
        csvec.csvec_zeros(1 << 16, cols=64, rows=4, signed=False),
        np.array([7], np.int32), np.ones(1, np.float32))
    for _ in range(2):
        one = csvec.decay(one, 0.5)
    assert float(csvec.query(one, np.array([7], np.int32))[0]) == 0.0


def test_serve_state_pspecs():
    """Slot-cache decode specs: kv leaves split-KV over model on the seq
    axis, per-slot vectors on the batch axis, key replicated."""
    from jax.sharding import PartitionSpec as P

    from repro.launch.shardings import serve_state_pspecs
    from repro.models.sharding import decode_rules

    cfg = reduced_config("gemma-2b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    serve = dataclasses.replace(cfg.serve, max_batch=2, max_seq=64)
    sched = SlotScheduler(cfg, params, serve=serve)
    rules = decode_rules(multi_pod=False, long_context=False)
    specs = serve_state_pspecs(cfg, sched.state, rules)
    k_spec = specs.cache["kv"]["k"]
    assert k_spec == P(None, rules["batch"], "model", None, None)
    assert specs.pos == P(rules["batch"])
    assert specs.forced == P(rules["batch"], None)
    assert specs.key == P(None)


def test_rtpm_nan_safe_selection():
    """A NaN/inf candidate can no longer hijack best-of-inits selection."""
    from repro.cpd.rtpm import _nan_safe_argmax
    vals = jnp.array([1.0, jnp.nan, 3.0, jnp.inf, 2.0])
    assert int(_nan_safe_argmax(vals)) == 2
    assert int(_nan_safe_argmax(jnp.array([jnp.nan, jnp.nan]))) == 0
