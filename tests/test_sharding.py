"""Sharding rules: divisibility of param specs on the production mesh for
every (arch, strategy), and a small-mesh end-to-end sharded train step in a
subprocess (8 host devices)."""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.configs.base import SHAPES, shape_applicable
from repro.configs.registry import ARCH_IDS, get_config
from repro.launch.shardings import (build_param_pspecs, cache_pspecs,
                                    make_rules)
from repro.models import model as M

_SIZE = {"data": 16, "model": 16, "pod": 2}


def _axes_size(entry):
    if entry is None:
        return 1
    if isinstance(entry, str):
        return _SIZE[entry]
    n = 1
    for a in entry:
        n *= _SIZE[a]
    return n


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("kind", ["train", "decode"])
def test_param_specs_divide_evenly(arch, kind):
    cfg = get_config(arch)
    rules, strategy = make_rules(cfg, kind, False, False)
    pspecs = M.param_specs(cfg)
    specs = build_param_pspecs(cfg, pspecs, rules, strategy)

    def check(path, leaf, spec):
        for dim, entry in zip(leaf.shape, tuple(spec)):
            size = _axes_size(entry)
            assert dim % size == 0, (arch, kind, path, leaf.shape, spec)
    jax.tree_util.tree_map_with_path(check, pspecs, specs)


@pytest.mark.parametrize("arch", ["yi-9b", "zamba2-2.7b", "xlstm-1.3b"])
def test_cache_specs_divide_evenly(arch):
    cfg = get_config(arch)
    for shape in SHAPES:
        if shape.kind != "decode" or not shape_applicable(cfg, shape):
            continue
        rules, _ = make_rules(cfg, "decode", shape.name == "long_500k", False)
        cspecs = M.input_specs(cfg, shape)["cache"]
        specs = cache_pspecs(cfg, cspecs, rules)

        def check(path, leaf, spec):
            for dim, entry in zip(leaf.shape, tuple(spec)):
                size = _axes_size(entry)
                assert dim % size == 0, (arch, shape.name, path,
                                         leaf.shape, spec)
        jax.tree_util.tree_map_with_path(check, cspecs, specs)


SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.registry import reduced_config
    from repro.models import model as M
    from repro.models.sharding import logical_rules
    from repro.launch.mesh import make_mesh

    # tiny (2 data, 4 model) mesh; reduced config; sharded vs unsharded
    # train step must agree.  (make_mesh guards the AxisType import, which
    # jax < 0.5 doesn't have.)
    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = reduced_config("yi-9b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32),
                                          0, cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32),
                                          0, cfg.vocab_size)}
    loss_ref, _ = jax.jit(M.make_train_step(cfg))(params, batch)

    rules = {"batch": ("data",), "seq": "model", "residual": "model",
             "chunks": "model", "ctx_shards": 4, "kv_seq": None,
             "heads": None, "kv_heads": None, "embed": None, "ff": None,
             "vocab": None, "experts": None, "expert_cap": None,
             "ssm_inner": None, "ssm_heads": None, "state": None,
             "zero": "data"}
    with mesh, logical_rules(rules):
        sharded = jax.jit(M.make_train_step(cfg))
        loss_sh, grads = sharded(params, batch)
    rel = abs(float(loss_sh) - float(loss_ref)) / max(abs(float(loss_ref)),
                                                      1e-6)
    assert rel < 0.02, (float(loss_sh), float(loss_ref))
    print("OK", float(loss_ref), float(loss_sh))
""")


@pytest.mark.slow
def test_sharded_train_step_matches_unsharded():
    p = subprocess.run([sys.executable, "-c", SCRIPT],
                       capture_output=True, text=True,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert p.returncode == 0, p.stderr[-3000:]
    assert "OK" in p.stdout
