"""End-to-end behaviour: a reduced model trains, checkpoints, serves, and
the sketched-head / grad-compression variants run through the same loop."""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import SketchConfig
from repro.configs.registry import reduced_config
from repro.models import model as M
from repro.serve.engine import ServeEngine
from repro.train.loop import train


def test_train_then_serve():
    cfg = reduced_config("gemma-2b")
    h = train(cfg, steps=20, batch=2, seq=32, lr=1e-3, log_every=1000,
              log_fn=lambda *_: None)
    assert all(jnp.isfinite(jnp.float32(l)) for l in h.losses)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, params, max_seq=48)
    out = engine.generate(jnp.ones((2, 8), jnp.int32), max_new=4)
    assert out.tokens.shape == (2, 4)
    assert int(out.tokens.max()) < cfg.vocab_size


def test_sketched_head_trains():
    cfg = dataclasses.replace(
        reduced_config("minitron-4b"),
        sketch=SketchConfig(sketched_head=True, head_hash_len=32))
    h = train(cfg, steps=40, batch=4, seq=32, lr=3e-3, log_every=1000,
              log_fn=lambda *_: None)
    assert h.losses[-1] < h.losses[0] + 0.1  # finite + not diverging
