"""Async serving front-end: streamed decode identity vs the batch
facade, cancellation / deadline expiry freeing pool blocks mid-flight,
priority preemption resuming bitwise, bounded-queue backpressure, and
the unified EngineStats snapshot."""
import asyncio
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.registry import reduced_config
from repro.models import model as M
from repro.serve.engine import ServeEngine
from repro.serve.frontend import AsyncServeEngine
from repro.serve.scheduler import Request, SlotScheduler


@pytest.fixture(scope="module")
def gemma():
    cfg = reduced_config("gemma-2b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, rng, n, lo=4, hi=20):
    return [rng.randint(0, cfg.vocab_size,
                        (rng.randint(lo, hi),)).astype(np.int32)
            for _ in range(n)]


def _assert_no_leaks(sched):
    """Every reserved pool block is accounted to the prefix cache once
    all slots retire — cancellations and expirations included."""
    if not sched.is_kv:
        return
    held = sched.prefix_cache.held_blocks()
    free = sched.alloc.free_count
    assert free + held == sched.num_blocks, (free, held, sched.num_blocks)


def test_stream_matches_batch_greedy_bitwise(gemma):
    """A greedy request's streamed tokens are BITWISE what the closed
    batch path produces for the same prompts — and the streaming side
    still compiles decode exactly once."""
    cfg, params = gemma
    serve = dataclasses.replace(cfg.serve, max_batch=3, max_seq=96,
                                decode_chunk=4, prefill_bucket=16)
    rng = np.random.RandomState(0)
    prompts = _prompts(cfg, rng, 6)
    ref = {c.rid: c.tokens for c in SlotScheduler(
        cfg, params, serve=serve).run(
        [Request(rid=i, tokens=p, max_new=5 + i % 3)
         for i, p in enumerate(prompts)])}

    front = AsyncServeEngine(cfg, params, serve=serve)

    async def go():
        handles = [await front.submit(p, max_new=5 + i % 3, rid=i)
                   for i, p in enumerate(prompts)]
        streamed = {}
        for h in handles:
            toks = [t async for t in h.stream()]
            streamed[h.rid] = toks
        return streamed, [h.completion for h in handles]

    streamed, done = asyncio.run(go())
    for c in done:
        assert c.status == "ok"
        np.testing.assert_array_equal(c.tokens, ref[c.rid],
                                      err_msg=f"rid {c.rid}")
        # the stream delivered exactly the completion's tokens, in order
        assert streamed[c.rid] == list(c.tokens)
    assert front._sched.decode_compilations == 1
    _assert_no_leaks(front._sched)


def test_cancel_midstream_frees_blocks_survivors_unchanged(gemma):
    """handle.cancel() mid-stream: the victim resolves with status
    "cancelled" holding only the tokens committed so far, its slot and
    blocks free (no leak at drain), and a concurrent survivor's output
    is bitwise what it decodes solo."""
    cfg, params = gemma
    serve = dataclasses.replace(cfg.serve, max_batch=2, max_seq=96,
                                decode_chunk=2, prefill_bucket=16)
    rng = np.random.RandomState(1)
    survivor, victim = _prompts(cfg, rng, 2, lo=6, hi=16)
    ref = SlotScheduler(cfg, params, serve=serve).run(
        [Request(rid=0, tokens=survivor, max_new=10)])[0]

    front = AsyncServeEngine(cfg, params, serve=serve)

    async def go():
        hs = await front.submit(survivor, max_new=10, rid=0)
        hv = await front.submit(victim, max_new=24, rid=1)

        async def consume_victim():
            n = 0
            async for _ in hv.stream():
                n += 1
                if n >= 3:
                    hv.cancel()
            return n

        _, cs, cv = await asyncio.gather(consume_victim(), hs.result(),
                                         hv.result())
        return cs, cv

    cs, cv = asyncio.run(go())
    assert cv.status == "cancelled"
    assert 0 < len(cv.tokens) < 24, "cancel should land mid-budget"
    assert cs.status == "ok"
    np.testing.assert_array_equal(cs.tokens, ref.tokens)
    assert front._sched.cancellations == 1
    _assert_no_leaks(front._sched)


def test_deadline_expiry_frees_blocks_survivor_unchanged(gemma):
    """An already-expired deadline resolves the request with status
    "expired" (partial output) at the next pump boundary; its blocks
    free, and the surviving request decodes bitwise unperturbed."""
    cfg, params = gemma
    serve = dataclasses.replace(cfg.serve, max_batch=2, max_seq=96,
                                decode_chunk=2, prefill_bucket=16)
    rng = np.random.RandomState(2)
    survivor, victim = _prompts(cfg, rng, 2, lo=6, hi=16)
    ref = SlotScheduler(cfg, params, serve=serve).run(
        [Request(rid=0, tokens=survivor, max_new=8)])[0]

    front = AsyncServeEngine(cfg, params, serve=serve)

    async def go():
        hs = await front.submit(survivor, max_new=8, rid=0)
        hv = await front.submit(victim, max_new=40, rid=1,
                                deadline_s=1e-6)
        return await asyncio.gather(hs.result(), hv.result())

    cs, cv = asyncio.run(go())
    assert cv.status == "expired"
    assert len(cv.tokens) < 40
    assert cs.status == "ok"
    np.testing.assert_array_equal(cs.tokens, ref.tokens)
    assert front._sched.expirations == 1
    _assert_no_leaks(front._sched)


def test_priority_preemption_resumes_bitwise(gemma):
    """A higher-priority arrival preempts the lowest-priority running
    slot at a pump boundary; the victim's blocks free for the newcomer
    and its continuation — requeued at the head of its band — finishes
    with tokens BITWISE identical to an uncontended run."""
    cfg, params = gemma
    serve = dataclasses.replace(cfg.serve, max_batch=1, max_seq=96,
                                decode_chunk=2, prefill_bucket=16)
    rng = np.random.RandomState(3)
    low_p, high_p = _prompts(cfg, rng, 2, lo=6, hi=16)
    ref = SlotScheduler(cfg, params, serve=serve).run(
        [Request(rid=0, tokens=low_p, max_new=12)])[0]

    sched = SlotScheduler(cfg, params, serve=serve)
    sched.submit(Request(rid=0, tokens=low_p, max_new=12, priority=0))
    done = sched.step()                       # 2 of 12 tokens committed
    assert not done
    sched.submit(Request(rid=1, tokens=high_p, max_new=4, priority=5))
    done = {c.rid: c for c in sched.drain()}
    assert sched.preemptions == 1
    assert done[1].status == "ok"
    # the preempted request resumed and its merged output is bitwise the
    # uncontended decode — positions, prompt_len and budget all survive
    # the evict/requeue/re-prefill round trip
    assert done[0].status == "ok"
    np.testing.assert_array_equal(done[0].tokens, ref.tokens)
    assert done[0].prompt_len == len(low_p)
    _assert_no_leaks(sched)


def test_preemption_respects_config_gate(gemma):
    """serve.preemption=False: a higher-priority arrival waits for a
    free slot instead of evicting — no preemption, both complete."""
    cfg, params = gemma
    serve = dataclasses.replace(cfg.serve, max_batch=1, max_seq=96,
                                decode_chunk=2, preemption=False)
    rng = np.random.RandomState(4)
    a, b = _prompts(cfg, rng, 2, lo=6, hi=12)
    sched = SlotScheduler(cfg, params, serve=serve)
    sched.submit(Request(rid=0, tokens=a, max_new=6, priority=0))
    sched.step()
    sched.submit(Request(rid=1, tokens=b, max_new=4, priority=5))
    done = {c.rid: c for c in sched.drain()}
    assert sched.preemptions == 0
    assert done[0].status == "ok" and done[1].status == "ok"
    _assert_no_leaks(sched)


def test_backpressure_defers_never_raises(gemma):
    """submit() past queue_depth parks the submitter on the space event
    — it defers, it never raises — and every request still completes.
    The scheduler queue never exceeds the configured bound."""
    cfg, params = gemma
    serve = dataclasses.replace(cfg.serve, max_batch=2, max_seq=96,
                                decode_chunk=2, queue_depth=2)
    rng = np.random.RandomState(5)
    prompts = _prompts(cfg, rng, 8, lo=4, hi=10)
    front = AsyncServeEngine(cfg, params, serve=serve)
    assert front.queue_depth == 2
    peak = 0

    async def go():
        nonlocal peak
        handles = []
        for i, p in enumerate(prompts):
            h = await front.submit(p, max_new=4, rid=i)
            peak = max(peak, front._sched.queue_len)
            handles.append(h)
        return await asyncio.gather(*[h.result() for h in handles])

    done = asyncio.run(go())
    assert len(done) == 8
    assert all(c.status == "ok" for c in done)
    assert peak <= 2, peak
    assert front._sched.decode_compilations == 1
    _assert_no_leaks(front._sched)


def test_spec_engine_cancel_storm_no_leaks(gemma):
    """Cancel storm against a SPECULATIVE engine: half the in-flight
    requests die at random chunk boundaries.  The draft pool mirrors the
    target pool's block ids, so the conservation assert covers both —
    free + cache-held == pool after drain, refcount books clean, and
    the survivors' greedy output stays bitwise identical to an
    uncontended speculative run."""
    cfg, params = gemma
    serve = dataclasses.replace(cfg.serve, max_batch=3, max_seq=96,
                                decode_chunk=2, prefill_bucket=16,
                                spec_k=2, draft_depth=1,
                                admit_threshold=1 << 30)
    rng = np.random.RandomState(6)
    prompts = _prompts(cfg, rng, 6, lo=6, hi=16)
    survivors = [0, 2, 4]
    ref = {c.rid: c.tokens for c in SlotScheduler(
        cfg, params, serve=serve).run(
        [Request(rid=i, tokens=prompts[i], max_new=8)
         for i in survivors])}

    sched = SlotScheduler(cfg, params, serve=serve)
    for i, p in enumerate(prompts):
        sched.submit(Request(rid=i, tokens=p, max_new=8))
    storm = [1, 3, 5]
    done = []
    while sched.pending:
        done.extend(sched.step())
        if storm:                              # one kill per boundary
            c = sched.cancel(storm.pop())
            if c is not None:
                done.append(c)
    by_rid = {c.rid: c for c in done}
    assert len(by_rid) == 6
    assert all(by_rid[r].status == "cancelled" for r in (1, 3, 5))
    for r in survivors:
        assert by_rid[r].status == "ok"
        np.testing.assert_array_equal(by_rid[r].tokens, ref[r],
                                      err_msg=f"rid {r}")
    assert sched.decode_compilations == 1
    # refcount books: reserved blocks all have holders, free ones none
    free = set(sched.alloc._free)
    for b in range(sched.num_blocks):
        assert (int(sched.alloc.rc[b]) == 0) == (b in free)
    _assert_no_leaks(sched)


def test_engine_stats_unified_snapshot(gemma):
    """ServeEngine.stats(): one merged EngineStats across schedulers —
    completions / cache counters / pool occupancy in a single flat
    snapshot, and format() renders without error."""
    cfg, params = gemma
    eng = ServeEngine(cfg, params)
    prompts = np.asarray(
        np.random.RandomState(7).randint(0, cfg.vocab_size, (2, 8)),
        np.int32)
    out = eng.generate(prompts, max_new=4)
    assert out.tokens.shape == (2, 4)
    st = eng.stats()
    assert st.completed == 2
    assert st.decode_compilations == 1
    assert st.cancelled == 0 and st.expired == 0
    text = st.format()
    assert "queue=" in text and "paged KV" in text
    # a second batch accumulates into the same snapshot
    eng.generate(prompts, max_new=4)
    assert eng.stats().completed == 4
