"""End-to-end engine contracts with the flash-decode paged-attention
Pallas kernels enabled (``ServeConfig.paged_kernels=True``, interpret
mode on CPU).

The kernel and the jnp gather path are numerically equivalent but not
bitwise-identical (online-softmax block order, bf16 p@v), so with
random-init weights greedy argmax can legitimately flip between the
implementations — cross-implementation checks therefore compare LOGITS
with tolerance (decode_step / verify_step on identical caches), while
the engine-level token assertions are the structural contracts that ARE
bitwise on the kernel path: speculative == plain, sketched anchor ==
sketch-free, run-to-run determinism, one decode compilation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import reduced_config
from repro.models import model as M
from repro.models import transformer as tf
from repro.serve.scheduler import Request, SlotScheduler


@pytest.fixture(scope="module")
def gemma():
    cfg = reduced_config("gemma-2b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _serve(cfg, **kw):
    base = dict(max_batch=2, max_seq=64, decode_chunk=4,
                prefill_bucket=16)
    base.update(kw)
    return dataclasses.replace(cfg.serve, **base)


def _reqs(cfg, lens, max_new=6, seed=0):
    rng = np.random.RandomState(seed)
    return [Request(rid=i,
                    tokens=rng.randint(0, cfg.vocab_size, (n,)).astype(
                        np.int32),
                    max_new=max_new)
            for i, n in enumerate(lens)]


def _run(cfg, params, serve, reqs):
    sched = SlotScheduler(cfg, params, serve=serve)
    return sched, {c.rid: list(c.tokens) for c in sched.run(reqs)}


def test_kernel_logits_match_jnp(gemma):
    """decode_step and verify_step on the SAME prefilled paged cache:
    kernels=True logits agree with kernels=False logits to bf16-level
    tolerance across every slot and verify row."""
    cfg, params = gemma
    B, bs, nper = 2, 16, 4
    tables = jnp.arange(B * nper, dtype=jnp.int32).reshape(B, nper)
    cache = tf.init_paged_cache(cfg, B * nper, bs)
    rng = np.random.RandomState(0)
    lens = [17, 30]
    for b, n in enumerate(lens):
        toks = np.zeros((1, 32), np.int32)
        toks[0, :n] = rng.randint(0, cfg.vocab_size, (n,))
        for s in (0, 16):
            cache = tf.prefill_chunk(
                params, cache, jnp.asarray(toks[:, s:s + 16]),
                tables[b], jnp.int32(s), cfg, kernels=False)
    cur = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, 1)), jnp.int32)
    pos = jnp.asarray(lens, jnp.int32)
    lg_k, _ = tf.decode_step(params, dict(cache), cur, pos, cfg,
                             tables=tables, kernels=True)
    lg_j, _ = tf.decode_step(params, dict(cache), cur, pos, cfg,
                             tables=tables, kernels=False)
    np.testing.assert_allclose(np.asarray(lg_k), np.asarray(lg_j),
                               rtol=5e-2, atol=5e-2)
    vt = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, 3)), jnp.int32)
    vg_k, _ = tf.verify_step(params, dict(cache), vt, pos, cfg,
                             tables=tables, kernels=True)
    vg_j, _ = tf.verify_step(params, dict(cache), vt, pos, cfg,
                             tables=tables, kernels=False)
    np.testing.assert_allclose(np.asarray(vg_k), np.asarray(vg_j),
                               rtol=5e-2, atol=5e-2)


def test_kernel_engine_deterministic_and_compiles_once(gemma):
    """A mixed-length stream (short prompts + chunk-prefilled prompts)
    through the kernel engine: every request completes with its full
    budget, decode and chunked prefill each compile exactly once, and a
    second identical run reproduces the tokens bitwise."""
    cfg, params = gemma
    lens = [5, 17, 9, 26]
    s, got = _run(cfg, params, _serve(cfg, paged_kernels=True),
                  _reqs(cfg, lens))
    assert s.use_kernels
    assert sorted(got) == list(range(len(lens)))
    assert all(len(t) == 6 for t in got.values())
    assert s.decode_compilations == 1
    assert s.prefill_compilations == 1
    _, again = _run(cfg, params, _serve(cfg, paged_kernels=True),
                    _reqs(cfg, lens))
    assert again == got


def test_kernel_spec_greedy_identity(gemma):
    """Greedy speculative decode with kernels on emits token-for-token
    what the plain kernel engine emits — the verify kernel's rows are
    bitwise its single-token decode rows, so acceptance only changes
    speed, never tokens."""
    cfg, params = gemma
    lens = [5, 14, 22]
    _, plain = _run(cfg, params,
                    _serve(cfg, paged_kernels=True), _reqs(cfg, lens))
    s, spec = _run(cfg, params,
                   _serve(cfg, paged_kernels=True, spec_k=2,
                          draft_depth=1), _reqs(cfg, lens))
    assert s.use_kernels
    assert spec == plain
    assert s.decode_compilations == 1


def test_kernel_sketched_anchor_and_fold(gemma):
    """Sketched engines on the kernel path: a window covering every
    context is bitwise the sketch-free kernel engine (the fold_base==0
    select picks pure kernel output), and a genuinely folding window
    (exact kernel window + sketched tail merged in one chunk) runs clean,
    deterministically, in one decode compilation."""
    cfg, params = gemma
    lens = [5, 19, 28]
    _, ref = _run(cfg, params, _serve(cfg, paged_kernels=True),
                  _reqs(cfg, lens))
    s, got = _run(cfg, params,
                  _serve(cfg, paged_kernels=True, kv_sketch_window=64),
                  _reqs(cfg, lens))
    assert s.use_kernels
    assert got == ref
    assert s.decode_compilations == 1
    sv_fold = dict(kv_sketch_window=16, max_seq=64, paged_kernels=True)
    reqs = lambda: _reqs(cfg, [40, 12], max_new=5, seed=1)
    sf, fold = _run(cfg, params, _serve(cfg, **sv_fold), reqs())
    assert sf.use_kernels
    assert all(len(t) == 5 for t in fold.values())
    assert sf.decode_compilations == 1
    _, fold2 = _run(cfg, params, _serve(cfg, **sv_fold), reqs())
    assert fold2 == fold


def test_paged_kernels_resolution(gemma):
    """paged_kernels=None auto-detects the backend exactly once at
    construction (False on CPU), and the flag is ignored for engines
    without a paged KV pool."""
    cfg, params = gemma
    s = SlotScheduler(cfg, params, serve=_serve(cfg))
    assert s.use_kernels == (jax.default_backend() == "tpu")
    xcfg = reduced_config("xlstm-1.3b")
    xp = M.init_params(jax.random.PRNGKey(0), xcfg)
    sx = SlotScheduler(
        xcfg, xp, serve=dataclasses.replace(
            xcfg.serve, max_batch=2, max_seq=48, decode_chunk=4,
            paged_kernels=True))
    assert not sx.use_kernels
