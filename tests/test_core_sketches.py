"""Core sketching correctness: structural identities (exact), statistical
properties (unbiasedness, variance ordering FCS <= TS, Cor.1 scaling), and
hypothesis property tests (linearity/scaling invariants)."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    # Fallback shim: hypothesis isn't installed in this container.  Run
    # each @given test over a small deterministic grid drawn from the
    # strategy bounds instead of failing collection.
    class _Strategy:
        def __init__(self, samples):
            self.samples = list(samples)

    class st:  # noqa: N801 - mimics the hypothesis module name
        @staticmethod
        def floats(lo, hi):
            return _Strategy([lo, hi, 0.5 * (lo + hi), 0.25 * lo + 0.75 * hi])

        @staticmethod
        def integers(lo, hi):
            return _Strategy([lo, hi, (lo + hi) // 2, lo + 12345 % max(hi - lo, 1)])

        @staticmethod
        def sampled_from(seq):
            return _Strategy(seq)

    def settings(**_kw):
        return lambda fn: fn

    def given(**strategies):
        names = list(strategies)

        def deco(fn):
            def wrapper(self, *a):
                grid = itertools.product(*(strategies[n].samples
                                           for n in names))
                for combo in itertools.islice(grid, 20):
                    fn(self, *a, **dict(zip(names, combo)))
            return wrapper
        return deco

from repro.core import (
    fcs_cp, fcs_general, fcs_kron_compress, fcs_kron_decompress,
    fcs_sketch_len, fcs_tiuu, hcs_cp, hcs_general,
    make_mode_hash, make_tensor_hashes, ts_cp, ts_general,
)
from repro.core.hashes import combined_fcs_hash

KEY = jax.random.PRNGKey(0)


def _cp_tensor(key, dims, R):
    ks = jax.random.split(key, len(dims) + 1)
    lam = jax.random.uniform(ks[0], (R,)) + 0.5
    Us = [jax.random.normal(k, (d, R)) for k, d in zip(ks[1:], dims)]
    T = jnp.einsum("ar,br,cr,r->abc", *Us, lam)
    return lam, Us, T


class TestStructuralIdentities:
    dims = (17, 13, 11)

    def setup_method(self, _):
        self.hashes = make_tensor_hashes(KEY, self.dims, 16, 3)
        self.lam, self.Us, self.T = _cp_tensor(jax.random.PRNGKey(1),
                                               self.dims, 4)

    def test_fcs_equals_structured_long_cs(self):
        """Eq. 6: FCS(T) == CS(vec(T)) under the structured hash pair."""
        sk = fcs_general(self.T, self.hashes)
        hc, sc = combined_fcs_hash(self.hashes)
        Jt = fcs_sketch_len([mh.J for mh in self.hashes])
        ref = jnp.stack([
            jnp.zeros(Jt).at[hc[d]].add(sc[d] * self.T.reshape(-1))
            for d in range(3)])
        np.testing.assert_allclose(sk, ref, rtol=1e-4, atol=1e-4)

    def test_fcs_cp_equals_general(self):
        """Eq. 8: the FFT fast path equals the definition."""
        np.testing.assert_allclose(fcs_cp(self.lam, self.Us, self.hashes),
                                   fcs_general(self.T, self.hashes),
                                   rtol=3e-3, atol=3e-3)

    def test_ts_cp_equals_general(self):
        np.testing.assert_allclose(ts_cp(self.lam, self.Us, self.hashes),
                                   ts_general(self.T, self.hashes),
                                   rtol=3e-3, atol=3e-3)

    def test_hcs_cp_equals_general(self):
        np.testing.assert_allclose(hcs_cp(self.lam, self.Us, self.hashes),
                                   hcs_general(self.T, self.hashes),
                                   rtol=3e-3, atol=3e-3)

    def test_fcs_sketch_len(self):
        assert fcs_sketch_len([16, 16, 16]) == 46
        assert fcs_sketch_len([4, 8]) == 11

    def test_tiuu_z_trick_equals_direct(self):
        """Eq. 17 == explicit <FCS(T), FCS(e_i o u o u)>."""
        hashes = make_tensor_hashes(jax.random.PRNGKey(3), (11, 11, 11),
                                    64, 3)
        _, Us, T = _cp_tensor(jax.random.PRNGKey(4), (11, 11, 11), 2)
        u = jax.random.normal(jax.random.PRNGKey(5), (11,))
        u = u / jnp.linalg.norm(u)
        sk = fcs_general(T, hashes)
        est = fcs_tiuu(sk, u, hashes)
        direct = []
        for i in range(11):
            e = jnp.zeros(11).at[i].set(1.0)
            ski = fcs_cp(jnp.ones(1), [e[:, None], u[:, None], u[:, None]],
                         hashes)
            direct.append(jnp.sum(sk * ski, axis=-1))
        np.testing.assert_allclose(est, jnp.stack(direct, axis=1),
                                   rtol=2e-3, atol=2e-3)


class TestStatistics:
    def test_inner_product_unbiased(self):
        """<FCS(M), FCS(N)> is a consistent estimator of <M, N> (Prop. 1)."""
        dims = (8, 8, 8)
        kM, kN = jax.random.split(jax.random.PRNGKey(2))
        M = jax.random.normal(kM, dims)
        N = jax.random.normal(kN, dims)
        exact = float(jnp.vdot(M, N))
        hashes = make_tensor_hashes(jax.random.PRNGKey(7), dims, 64, 256)
        est = jnp.sum(fcs_general(M, hashes) * fcs_general(N, hashes),
                      axis=-1)
        mean = float(jnp.mean(est))
        sem = float(jnp.std(est) / np.sqrt(256))
        assert abs(mean - exact) < 5 * sem + 1e-3

    def test_fcs_variance_not_worse_than_ts(self):
        """Prop. 1 (Eq. 14): Var[FCS estimator] <= Var[TS estimator] under
        equalized hashes.  Checked empirically over repetitions."""
        dims = (8, 8, 8)
        kM, kN = jax.random.split(jax.random.PRNGKey(2))
        M = jax.random.normal(kM, dims)
        N = jax.random.normal(kN, dims)
        hashes = make_tensor_hashes(jax.random.PRNGKey(11), dims, 32, 512)
        e_fcs = jnp.sum(fcs_general(M, hashes) * fcs_general(N, hashes), -1)
        e_ts = jnp.sum(ts_general(M, hashes) * ts_general(N, hashes), -1)
        v_fcs = float(jnp.var(e_fcs))
        v_ts = float(jnp.var(e_ts))
        assert v_fcs <= v_ts * 1.10  # 10% slack for sampling noise

    def test_variance_scales_inversely_with_J(self):
        """Cor. 1: estimator variance ~ ||T||^2 / J."""
        dims = (8, 8, 8)
        M = jax.random.normal(jax.random.PRNGKey(2), dims)
        N = jax.random.normal(jax.random.PRNGKey(3), dims)
        vs = []
        for J in (16, 64):
            hashes = make_tensor_hashes(jax.random.PRNGKey(13), dims, J, 384)
            e = jnp.sum(fcs_general(M, hashes) * fcs_general(N, hashes), -1)
            vs.append(float(jnp.var(e)))
        # J x4 => variance should drop noticeably (allow wide slack)
        assert vs[1] < vs[0] * 0.6

    def test_norm_preservation(self):
        dims = (10, 10, 10)
        T = jax.random.normal(jax.random.PRNGKey(5), dims)
        hashes = make_tensor_hashes(jax.random.PRNGKey(6), dims, 256, 64)
        sk = fcs_general(T, hashes)
        norms = jnp.sum(sk ** 2, axis=-1)
        rel = float(jnp.abs(jnp.mean(norms) - jnp.sum(T ** 2))
                    / jnp.sum(T ** 2))
        assert rel < 0.15


class TestHypothesisProperties:
    @settings(max_examples=20, deadline=None)
    @given(scale=st.floats(-3.0, 3.0),
           seed=st.integers(0, 2 ** 16))
    def test_linearity_scaling(self, scale, seed):
        """FCS(a*T) == a*FCS(T) (sketches are linear maps)."""
        dims = (5, 6, 7)
        T = jax.random.normal(jax.random.PRNGKey(seed % 97), dims)
        hashes = make_tensor_hashes(jax.random.PRNGKey(seed), dims, 8, 2)
        a = jnp.float32(scale)
        np.testing.assert_allclose(fcs_general(a * T, hashes),
                                   a * fcs_general(T, hashes),
                                   rtol=1e-3, atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2 ** 16))
    def test_additivity(self, seed):
        dims = (5, 6, 7)
        kA, kB = jax.random.split(jax.random.PRNGKey(seed % 89))
        A = jax.random.normal(kA, dims)
        B = jax.random.normal(kB, dims)
        hashes = make_tensor_hashes(jax.random.PRNGKey(seed), dims, 8, 2)
        np.testing.assert_allclose(
            fcs_general(A + B, hashes),
            fcs_general(A, hashes) + fcs_general(B, hashes),
            rtol=1e-3, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2 ** 16),
           J=st.sampled_from([4, 8, 16]))
    def test_hash_range(self, seed, J):
        mh = make_mode_hash(jax.random.PRNGKey(seed), 50, J, 3)
        assert int(mh.h.min()) >= 0 and int(mh.h.max()) < J
        assert set(np.unique(np.asarray(mh.s))).issubset({-1.0, 1.0})


def test_kron_compress_decompress_improves_with_J():
    A = jax.random.normal(jax.random.PRNGKey(1), (6, 5))
    B = jax.random.normal(jax.random.PRNGKey(2), (4, 7))
    K = jnp.kron(A, B)
    errs = []
    for J in (64, 512):
        hk = make_tensor_hashes(jax.random.PRNGKey(3), (6, 5, 4, 7), J, 9)
        Khat = fcs_kron_decompress(fcs_kron_compress(A, B, hk), hk,
                                   (6, 5), (4, 7))
        errs.append(float(jnp.linalg.norm(Khat - K) / jnp.linalg.norm(K)))
    assert errs[1] < errs[0]
