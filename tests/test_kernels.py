"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp
oracles (interpret mode executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.count_sketch import count_sketch
from repro.kernels.unsketch import unsketch
from repro.kernels.ops import count_sketch_op, unsketch_op

SHAPES = [(1, 64, 32), (4, 1000, 256), (2, 300, 64), (8, 4096, 512),
          (1, 50, 300), (3, 128, 128)]
BLOCKS = [(2, 128, 128), (4, 256, 64)]


def _inputs(B, I, J, dtype, seed=0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (B, I)).astype(dtype)
    h = jax.random.randint(ks[1], (I,), 0, J)
    s = (1.0 - 2.0 * jax.random.randint(ks[2], (I,), 0, 2)
         ).astype(jnp.float32)
    return x, h, s


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("blocks", BLOCKS)
def test_count_sketch_matches_ref_f32(shape, blocks):
    B, I, J = shape
    bB, bI, bJ = blocks
    x, h, s = _inputs(B, I, J, jnp.float32)
    out = count_sketch(x, h, s, J, bB=bB, bI=bI, bJ=bJ)
    np.testing.assert_allclose(out, ref.count_sketch_ref(x, h, s, J),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", SHAPES)
def test_unsketch_matches_ref_f32(shape):
    B, I, J = shape
    _, h, s = _inputs(B, I, J, jnp.float32)
    y = jax.random.normal(jax.random.PRNGKey(9), (B, J))
    out = unsketch(y, h, s, bB=2, bI=128, bJ=128)
    np.testing.assert_allclose(out, ref.unsketch_ref(y, h, s),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.bfloat16, 3e-2),
                                       (jnp.float32, 2e-5)])
def test_count_sketch_dtypes(dtype, tol):
    x, h, s = _inputs(4, 512, 128, dtype)
    out = count_sketch(x, h, s, 128)
    refv = ref.count_sketch_ref(x.astype(jnp.float32), h, s, 128)
    np.testing.assert_allclose(out.astype(jnp.float32), refv,
                               rtol=tol, atol=tol)


def test_roundtrip_unbiased_entries():
    """unsketch(count_sketch(x)) has the right diagonal (each entry
    contains its own value plus zero-mean collision noise)."""
    B, I, J = 1, 256, 4096
    x, h, s = _inputs(B, I, J, jnp.float32, seed=3)
    y = count_sketch(x, h, s, J)
    xhat = unsketch(y, h, s)
    err = jnp.abs(xhat - x)
    # J >> I: expected collision-free fraction ~ (1 - 1/J)^(I-1) ~ 94%
    frac_exact = float(jnp.mean(err < 1e-4))
    assert frac_exact > 0.85, frac_exact
    assert float(jnp.median(err)) < 1e-5


def test_ops_dispatch():
    x, h, s = _inputs(2, 200, 64, jnp.float32)
    a = count_sketch_op(x, h, s, 64, use_pallas=True)
    b = count_sketch_op(x, h, s, 64, use_pallas=False)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)
    y = jax.random.normal(jax.random.PRNGKey(1), (2, 64))
    a = unsketch_op(y, h, s, use_pallas=True)
    b = unsketch_op(y, h, s, use_pallas=False)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)
