"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp
oracles (interpret mode executes the kernel body on CPU).

The CI kernel-oracle matrix job selects one kernel family per matrix
entry with ``pytest tests/test_kernels.py -k <family>`` — every
kernel/ref pair in ``kernels/ref.py`` has at least one test here whose
name contains its family (count_sketch, unsketch, sketch_update,
kv_tail, paged_attention), so no kernel can drift from its oracle
unexercised."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.count_sketch import count_sketch
from repro.kernels.ops import (count_sketch_op, paged_attention_op,
                               unsketch_op)
from repro.kernels.paged_attention import paged_attention
from repro.kernels.unsketch import unsketch

SHAPES = [(1, 64, 32), (4, 1000, 256), (2, 300, 64), (8, 4096, 512),
          (1, 50, 300), (3, 128, 128)]
BLOCKS = [(2, 128, 128), (4, 256, 64)]


def _inputs(B, I, J, dtype, seed=0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (B, I)).astype(dtype)
    h = jax.random.randint(ks[1], (I,), 0, J)
    s = (1.0 - 2.0 * jax.random.randint(ks[2], (I,), 0, 2)
         ).astype(jnp.float32)
    return x, h, s


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("blocks", BLOCKS)
def test_count_sketch_matches_ref_f32(shape, blocks):
    B, I, J = shape
    bB, bI, bJ = blocks
    x, h, s = _inputs(B, I, J, jnp.float32)
    out = count_sketch(x, h, s, J, bB=bB, bI=bI, bJ=bJ)
    np.testing.assert_allclose(out, ref.count_sketch_ref(x, h, s, J),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", SHAPES)
def test_unsketch_matches_ref_f32(shape):
    B, I, J = shape
    _, h, s = _inputs(B, I, J, jnp.float32)
    y = jax.random.normal(jax.random.PRNGKey(9), (B, J))
    out = unsketch(y, h, s, bB=2, bI=128, bJ=128)
    np.testing.assert_allclose(out, ref.unsketch_ref(y, h, s),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.bfloat16, 3e-2),
                                       (jnp.float32, 2e-5)])
def test_count_sketch_dtypes(dtype, tol):
    x, h, s = _inputs(4, 512, 128, dtype)
    out = count_sketch(x, h, s, 128)
    refv = ref.count_sketch_ref(x.astype(jnp.float32), h, s, 128)
    np.testing.assert_allclose(out.astype(jnp.float32), refv,
                               rtol=tol, atol=tol)


def test_roundtrip_unbiased_entries():
    """unsketch(count_sketch(x)) has the right diagonal (each entry
    contains its own value plus zero-mean collision noise)."""
    B, I, J = 1, 256, 4096
    x, h, s = _inputs(B, I, J, jnp.float32, seed=3)
    y = count_sketch(x, h, s, J)
    xhat = unsketch(y, h, s)
    err = jnp.abs(xhat - x)
    # J >> I: expected collision-free fraction ~ (1 - 1/J)^(I-1) ~ 94%
    frac_exact = float(jnp.mean(err < 1e-4))
    assert frac_exact > 0.85, frac_exact
    assert float(jnp.median(err)) < 1e-5


def test_ops_dispatch():
    x, h, s = _inputs(2, 200, 64, jnp.float32)
    a = count_sketch_op(x, h, s, 64, use_pallas=True)
    b = count_sketch_op(x, h, s, 64, use_pallas=False)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)
    y = jax.random.normal(jax.random.PRNGKey(1), (2, 64))
    a = unsketch_op(y, h, s, use_pallas=True)
    b = unsketch_op(y, h, s, use_pallas=False)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# sketch_update / kv_tail kernels vs ref (pair coverage for the CI matrix;
# deeper sweeps live in test_sketch_opt.py / test_kv_sketch.py)
# ---------------------------------------------------------------------------


def test_sketch_update_matches_ref():
    from repro.kernels.sketch_update import sketch_update
    from repro.sketch.hashing import cached_coeffs

    rng = np.random.RandomState(0)
    n, R, C = 700, 3, 128
    g = jnp.asarray(rng.randn(n).astype(np.float32))
    m_t = jnp.asarray(rng.randn(R, C).astype(np.float32))
    v_t = jnp.abs(jnp.asarray(rng.randn(R, C).astype(np.float32)))
    cm, cv = cached_coeffs(3, R), cached_coeffs(5, R)
    got = sketch_update(g, m_t, v_t, cm, cv, b1=0.9, b2=0.95)
    want = ref.sketch_update_ref(g, m_t, v_t, cm, cv, 0.9, 0.95)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_kv_tail_fold_matches_ref():
    from repro.kernels import kv_sketch as kk
    from repro.sketch.hashing import cached_coeffs

    rng = np.random.RandomState(2)
    Z, C, D, N, T = 3, 32, 48, 90, 160
    coeffs = cached_coeffs(7, Z)
    rows = jnp.asarray(rng.randn(N, D).astype(np.float32))
    pos = jnp.asarray(rng.randint(0, T, (N,)).astype(np.int32))
    tail = jnp.asarray(rng.randn(Z, C, D).astype(np.float32))
    got = kk.tail_fold(rows, pos, tail, coeffs, bN=32, bC=32)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.kv_tail_fold_ref(rows, pos, tail,
                                                         coeffs)),
        atol=1e-4)


def test_kv_tail_scores_matches_ref():
    from repro.kernels import kv_sketch as kk
    from repro.sketch.hashing import cached_coeffs

    rng = np.random.RandomState(3)
    Z, C, D, N, T = 3, 32, 48, 20, 130
    coeffs = cached_coeffs(11, Z)
    q = jnp.asarray(rng.randn(N, D).astype(np.float32))
    tail_k = jnp.asarray(rng.randn(Z, C, D).astype(np.float32))
    got = kk.tail_scores(q, tail_k, coeffs, T=T, bN=16, bT=64)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.kv_tail_scores_ref(q, tail_k,
                                                           coeffs, T)),
        atol=1e-4)


# ---------------------------------------------------------------------------
# paged attention (flash-decode over block tables)
# ---------------------------------------------------------------------------

# (B, Sq, K, R, hd, NB, bs, nb): decode-, verify- and chunk-shaped cases
PAGED_SHAPES = [
    (3, 1, 2, 3, 16, 12, 8, 4),     # single-token decode, GQA
    (2, 4, 2, 2, 32, 10, 4, 5),     # speculative verify (C = 4)
    (1, 16, 1, 4, 16, 8, 8, 6),     # chunked prefill (one slot), MQA
    (4, 3, 3, 1, 8, 20, 16, 3),     # R == 1 (MHA-as-GQA degenerate)
]


def _paged_inputs(B, Sq, K, R, hd, NB, bs, nb, seed=0,
                  dtype=jnp.bfloat16):
    """Ragged per-slot geometry: every slot gets its own start position
    (some mid-block, some spanning several blocks), slot 0 gets an
    invalidated table row, and fold_base mixes zero / nonzero."""
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(B, Sq, K, R, hd), dtype)
    kp = jnp.asarray(rng.randn(NB, bs, K, hd), dtype)
    vp = jnp.asarray(rng.randn(NB, bs, K, hd), dtype)
    tables = jnp.asarray(
        rng.permutation(NB)[:B * nb].reshape(B, nb)
        if B * nb <= NB else rng.randint(0, NB, (B, nb)), jnp.int32)
    tables = tables.at[0, nb - 1].set(NB)          # invalidated row
    start = jnp.asarray(rng.randint(0, nb * bs - Sq, (B,)), jnp.int32)
    fb = jnp.asarray([0] * (B - B // 2) + [bs] * (B // 2), jnp.int32)
    fb = jnp.minimum(fb, start)    # window always contains the query row
    return q, kp, vp, tables, start, fb


@pytest.mark.parametrize("shape", PAGED_SHAPES)
def test_paged_attention_matches_ref(shape):
    """Interpret-mode kernel vs the jnp online-softmax oracle: the block
    loop is op-for-op identical, so the statistics agree to ~bitwise
    (asserted at rtol 1e-5, well below the acceptance bar)."""
    got = paged_attention(*_paged_inputs(*shape))
    want = ref.paged_attention_ref(*_paged_inputs(*shape))
    for name, a, b in zip("m l acc".split(), got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6, err_msg=name)


def test_paged_attention_ragged_lengths_dense_oracle():
    """Normalized kernel output vs a full-softmax f32 oracle computed
    per slot over the gathered span — checks the mask semantics (per-row
    causal bound, fold_base lower bound, dead blocks) rather than the
    update equations."""
    B, Sq, K, R, hd, NB, bs, nb = 2, 4, 2, 2, 16, 10, 4, 5
    q, kp, vp, tables, start, fb = _paged_inputs(B, Sq, K, R, hd, NB, bs,
                                                 nb, seed=5)
    m, l, acc = paged_attention(q, kp, vp, tables, start, fb)
    out = np.asarray(acc / jnp.maximum(l, 1e-30)[..., None])
    S = nb * bs
    kt = np.asarray(jnp.take(kp, tables, axis=0, mode="fill",
                             fill_value=0), np.float32).reshape(B, S, K, hd)
    vt = np.asarray(jnp.take(vp, tables, axis=0, mode="fill",
                             fill_value=0), np.float32).reshape(B, S, K, hd)
    qf = np.asarray(q, np.float32)
    kpos = np.arange(S)
    blk_ok = np.repeat(np.asarray(tables) < NB, bs).reshape(B, S)
    scale = 1.0 / np.sqrt(hd)
    for b in range(B):
        for i in range(Sq):
            live = ((kpos <= int(start[b]) + i)
                    & (kpos >= int(fb[b])) & blk_ok[b])
            for z in range(K):
                for r in range(R):
                    s = kt[b, :, z] @ qf[b, i, z, r] * scale
                    s = np.where(live, s, -1e30)
                    w = np.where(live, np.exp(s - s.max()), 0.0)
                    o = (w @ vt[b, :, z]) / max(w.sum(), 1e-30)
                    np.testing.assert_allclose(out[b, z, r, i], o,
                                               rtol=2e-2, atol=2e-2)


def test_paged_attention_invalid_rows_drop():
    """Pool blocks behind invalidated table entries (>= NB) contribute
    nothing: scribbling huge values into every block the tables do NOT
    reference — including the block an invalidated entry would clamp to
    — leaves the statistics unchanged."""
    B, Sq, K, R, hd, NB, bs, nb = 2, 2, 2, 2, 16, 12, 4, 3
    q, kp, vp, tables, start, fb = _paged_inputs(B, Sq, K, R, hd, NB, bs,
                                                 nb, seed=7)
    ref_out = paged_attention(q, kp, vp, tables, start, fb)
    used = set(np.asarray(tables)[np.asarray(tables) < NB].tolist())
    unused = [j for j in range(NB) if j not in used]
    assert unused, "fixture must leave unreferenced pool blocks"
    kp2, vp2 = np.asarray(kp, np.float32), np.asarray(vp, np.float32)
    kp2[unused] = 1e4
    vp2[unused] = -1e4
    got = paged_attention(q, jnp.asarray(kp2, kp.dtype),
                          jnp.asarray(vp2, vp.dtype), tables, start, fb)
    for a, b in zip(ref_out, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_paged_attention_decode_rows_bitwise_match_verify():
    """A single-token decode call at position start + i reproduces row i
    of the multi-query verify call BITWISE — the kernel-side anchor that
    keeps greedy speculative decode identical to plain greedy decode."""
    B, Sq, K, R, hd, NB, bs, nb = 2, 4, 2, 2, 16, 10, 4, 5
    q, kp, vp, tables, start, fb = _paged_inputs(B, Sq, K, R, hd, NB, bs,
                                                 nb, seed=9)
    mv, lv, av = paged_attention(q, kp, vp, tables, start, fb)
    for i in range(Sq):
        m1, l1, a1 = paged_attention(q[:, i:i + 1], kp, vp, tables,
                                     start + i, fb)
        np.testing.assert_array_equal(np.asarray(m1[..., 0]),
                                      np.asarray(mv[..., i]))
        np.testing.assert_array_equal(np.asarray(l1[..., 0]),
                                      np.asarray(lv[..., i]))
        np.testing.assert_array_equal(np.asarray(a1[..., 0, :]),
                                      np.asarray(av[..., i, :]))


def test_paged_attention_ops_dispatch():
    shape = PAGED_SHAPES[1]
    args = _paged_inputs(*shape, seed=11)
    got = paged_attention_op(*args, use_pallas=True)
    want = paged_attention_op(*args, use_pallas=False)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_paged_attention_traced_start():
    """chunk_attention passes a TRACED start offset — one compilation
    must serve every offset, so the kernel has to accept start/fold_base
    as runtime values."""
    shape = PAGED_SHAPES[2]
    q, kp, vp, tables, start, fb = _paged_inputs(*shape, seed=13)

    calls = jax.jit(lambda s: paged_attention(q, kp, vp, tables, s, fb))
    a = calls(start)
    b = calls(start + 4)
    assert calls._cache_size() == 1
    assert not np.array_equal(np.asarray(a[0]), np.asarray(b[0]))
