"""Paper Fig. 6: mode-contraction compression A o_{3,1} B — CS vs HCS vs
FCS: compress/decompress time, relative error, hash memory.

Exact paper sizes: A (30,40,50), B (50,40,30) uniform [0,10]; D=20.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_once
from repro.core import (
    cs_apply, cs_unsketch, fcs_contraction_compress,
    fcs_contraction_decompress, fcs_sketch_len, make_mode_hash,
    make_tensor_hashes, storage_bytes_cs_long, storage_bytes_tabulated,
)
from repro.core.sketches import hcs_general

SHA, SHB = (30, 40, 50), (50, 40, 30)
OUT = (30, 40, 40, 30)


def run(crs=(2, 4, 8, 16), D=20, seed=0):
    key = jax.random.PRNGKey(seed)
    kA, kB = jax.random.split(key)
    A = jax.random.uniform(kA, SHA, minval=0.0, maxval=10.0)
    B = jax.random.uniform(kB, SHB, minval=0.0, maxval=10.0)
    Cx = jnp.einsum("abl,lcd->abcd", A, B)
    numel = Cx.size
    dims = OUT

    for cr in crs:
        Jt = max(8, numel // cr)
        J = max(2, (Jt + 3) // 4)
        Jt = fcs_sketch_len([J] * 4)
        hashes = make_tensor_hashes(jax.random.fold_in(key, cr), dims, J, D)
        f_c = jax.jit(lambda a, b: fcs_contraction_compress(a, b, hashes))
        sec_c, sk = time_once(f_c, A, B)
        f_d = jax.jit(lambda s: fcs_contraction_decompress(s, hashes, OUT))
        sec_d, Ch = time_once(f_d, sk)
        err = float(jnp.linalg.norm(Ch - Cx) / jnp.linalg.norm(Cx))
        emit(f"contract_fig6/fcs/cr{cr}", sec_c,
             f"decomp_us={sec_d*1e6:.0f};rel_err={err:.4f};"
             f"hash_bytes={storage_bytes_tabulated(hashes)}")
        # HCS on the contraction result structure: sum_l HCS(A_l) x HCS(B_l)
        Jh = max(2, round(Jt ** 0.25))
        hh = make_tensor_hashes(jax.random.fold_in(key, cr + 100), dims,
                                Jh, D)

        def hcs_c(a, b):
            skA = jax.vmap(lambda l: hcs_general(a[:, :, l], hh[:2]),
                           out_axes=-1)(jnp.arange(SHA[-1]))
            skB = jax.vmap(lambda l: hcs_general(b[l], hh[2:]),
                           out_axes=-1)(jnp.arange(SHB[0]))
            return jnp.einsum("dabl,dcel->dabce", skA, skB)
        h_c = jax.jit(hcs_c)
        sec_c, skh = time_once(h_c, A, B)

        def hcs_d(s):
            def one(d):
                g = s[d][hh[0].h[d][:, None, None, None],
                         hh[1].h[d][None, :, None, None],
                         hh[2].h[d][None, None, :, None],
                         hh[3].h[d][None, None, None, :]]
                sign = (hh[0].s[d][:, None, None, None]
                        * hh[1].s[d][None, :, None, None]
                        * hh[2].s[d][None, None, :, None]
                        * hh[3].s[d][None, None, None, :])
                return sign * g
            return jnp.median(jax.lax.map(one, jnp.arange(D)), axis=0)
        h_d = jax.jit(hcs_d)
        sec_d, Chh = time_once(h_d, skh)
        err = float(jnp.linalg.norm(Chh - Cx) / jnp.linalg.norm(Cx))
        emit(f"contract_fig6/hcs/cr{cr}", sec_c,
             f"decomp_us={sec_d*1e6:.0f};rel_err={err:.4f};"
             f"hash_bytes={storage_bytes_tabulated(hh)}")
        # CS on the materialized contraction
        mh = make_mode_hash(jax.random.fold_in(key, cr + 200), numel, Jt, D)
        c_c = jax.jit(lambda a, b: cs_apply(
            jnp.einsum("abl,lcd->abcd", a, b).reshape(-1), mh))
        sec_c, skc = time_once(c_c, A, B)
        c_d = jax.jit(lambda s: cs_unsketch(s, mh))
        sec_d, Cc2 = time_once(c_d, skc)
        err = float(jnp.linalg.norm(Cc2.reshape(OUT) - Cx)
                    / jnp.linalg.norm(Cx))
        emit(f"contract_fig6/cs/cr{cr}", sec_c,
             f"decomp_us={sec_d*1e6:.0f};rel_err={err:.4f};"
             f"hash_bytes={storage_bytes_cs_long(dims, D)}")


def main():
    argparse.ArgumentParser().parse_args()
    run()


if __name__ == "__main__":
    main()
