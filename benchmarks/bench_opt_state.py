"""Beyond-paper: sketched optimizer state — dense vs count-sketch AdamW
moments (repro.sketch): step time, state bytes, and loss tracking across
compression ratios on a synthetic param/grad pytree."""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.sketch.optimizer import (moment_state_bytes, sketched_adamw_init,
                                    sketched_adamw_update)
from repro.train.optimizer import adamw_init, adamw_update


def _params(dims, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), len(dims))
    return {f"w{i}": 0.02 * jax.random.normal(k, (d,))
            for i, (k, d) in enumerate(zip(ks, dims))}


def _grads(params, t):
    k = jax.random.PRNGKey(1000 + t)
    ks = jax.random.split(k, len(params))
    # heavy-tailed-ish gradients (closer to LM training than pure gaussian)
    return {n: jax.random.normal(kk, p.shape)
            * (1.0 + 10.0 * (jax.random.uniform(kk, p.shape) > 0.99))
            for kk, (n, p) in zip(ks, params.items())}


def run(dims=(1 << 20, 1 << 18, 1 << 14), ratios=(2, 4, 8), steps=20,
        seed=0):
    params = _params(dims, seed)
    g0 = _grads(params, 0)

    # dense baseline
    opt = adamw_init(params)
    f_dense = jax.jit(lambda g, o, p: adamw_update(g, o, p, lr=1e-3))
    sec = timeit(f_dense, g0, opt, params)
    dense_bytes = sum(l.size * 4 for l in jax.tree.leaves(opt.m)) \
        + sum(l.size * 4 for l in jax.tree.leaves(opt.v))
    emit("opt_state/dense/step", sec, f"state_bytes={dense_bytes}")

    for r in ratios:
        opt_s = sketched_adamw_init(params, ratio=r, rows=3,
                                    min_elems=1 << 13, seed=seed)
        f_sk = jax.jit(lambda g, o, p: sketched_adamw_update(
            g, o, p, lr=1e-3))
        sec = timeit(f_sk, g0, opt_s, params)
        b = moment_state_bytes(opt_s)
        shrink = b["sketched_dense_equiv"] / max(b["sketched"], 1)
        emit(f"opt_state/sketched/r{r}/step", sec,
             f"state_bytes={b['total']};shrink_x={shrink:.2f}")

    # short convergence comparison on a quadratic at ratio 4
    target = jax.tree.map(
        lambda p: jax.random.normal(jax.random.PRNGKey(7), p.shape), params)

    def quad_run(update, opt, w, lr):
        upd = jax.jit(lambda g, o, p: update(g, o, p, lr))
        for _ in range(steps):
            g = jax.tree.map(lambda x, t: x - t, w, target)
            w, opt = upd(g, opt, w)
        err = jnp.sqrt(sum(jnp.sum((a - b) ** 2) for a, b in
                           zip(jax.tree.leaves(w),
                               jax.tree.leaves(target))))
        return float(err)

    e_d = quad_run(lambda g, o, p, lr: adamw_update(g, o, p, lr=lr),
                   adamw_init(params), params, 5e-2)
    e_s = quad_run(
        lambda g, o, p, lr: sketched_adamw_update(g, o, p, lr=lr),
        sketched_adamw_init(params, ratio=4, rows=3, min_elems=1 << 13),
        params, 5e-2)
    emit(f"opt_state/quad_err_{steps}steps", 0.0,
         f"dense={e_d:.4f};sketched_r4={e_s:.4f}")


def main():
    argparse.ArgumentParser().parse_args()
    run()


if __name__ == "__main__":
    main()
