"""Run every paper-table benchmark; print ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="trim grids for a quick pass")
    args, _ = ap.parse_known_args()

    print("name,us_per_call,derived")
    t0 = time.time()

    from benchmarks import (bench_als, bench_contract, bench_grad_compress,
                            bench_kron, bench_opt_state, bench_rtpm,
                            bench_serve, bench_trl)

    if args.fast:
        bench_rtpm.run(I=40, Js=(400,), table2=False)
        bench_als.run(I=40, Js=(800,), D=4, iters=8)
        bench_trl.run(crs=(20, 100), n_train=512, n_test=256)
        bench_kron.run(crs=(4, 16), D=8)
        bench_contract.run(crs=(4, 16), D=8)
        bench_grad_compress.run(dims=1 << 18, ratios=(16,))
        bench_opt_state.run(dims=(1 << 17, 1 << 13), ratios=(4,), steps=10)
        bench_serve.run(n_requests=8, max_new=4, max_batch=2)
    else:
        bench_rtpm.run()
        bench_als.run()
        bench_trl.run()
        bench_kron.run()
        bench_contract.run()
        bench_grad_compress.run()
        bench_opt_state.run()
        bench_serve.run()

    print(f"# total benchmark wall time: {time.time()-t0:.1f}s",
          file=sys.stderr)


if __name__ == "__main__":
    main()
