"""Run every paper-table benchmark; print ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--only a,b] [--json f]

``--json`` additionally writes the collected rows as a JSON list of
{name, us_per_call, derived, metrics, ts, sha} objects — the CI
bench-smoke job uploads it as a per-PR artifact so the perf trajectory
is recorded; ``ts`` (UTC wall clock) and ``sha`` (git commit) make
artifacts self-identifying when compared out of band.  ``--only``
restricts the pass to a comma-separated subset of benchmark modules
(e.g. ``--only serve,opt_state``).  ``--metrics-jsonl`` hands the serve
bench a path for its observability-overhead row to stream windowed
metrics snapshots to (uploaded as a CI artifact next to the bench JSON).
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import subprocess
import sys
import time


def _parse_derived(derived: str) -> dict:
    """Split a ``k=v;k=v`` derived string into typed metric columns so the
    JSON artifact carries comparable numbers (tok_s, hit_rate, the paged
    kv_*_bytes columns, ...) instead of one opaque string."""
    metrics = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            metrics[k] = float(v.rstrip("x"))
        except ValueError:
            metrics[k] = v
    return metrics


def _git_sha() -> str:
    """Commit identity for the artifact: CI env first (works in shallow
    or detached checkouts), then git, then a placeholder."""
    sha = os.environ.get("GITHUB_SHA", "")
    if sha:
        return sha[:12]
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short=12", "HEAD"],
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def _write_json(path: str) -> None:
    from benchmarks.common import ROWS
    ts = datetime.datetime.now(datetime.timezone.utc).isoformat(
        timespec="seconds")
    sha = _git_sha()
    rows = []
    for r in ROWS:
        name, us, derived = r.split(",", 2)
        rows.append({"name": name, "us_per_call": float(us),
                     "derived": derived,
                     "metrics": _parse_derived(derived),
                     "ts": ts, "sha": sha})
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"# wrote {len(rows)} rows to {path} (sha={sha} ts={ts})",
          file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="trim grids for a quick pass")
    ap.add_argument("--only", default="",
                    help="comma-separated subset of benches "
                         "(rtpm,als,trl,kron,contract,grad_compress,"
                         "opt_state,serve)")
    ap.add_argument("--json", default="",
                    help="also write rows as JSON to this path")
    ap.add_argument("--metrics-jsonl", default="",
                    help="serve bench: stream windowed observability "
                         "metrics (JSONL) from the obs_overhead row to "
                         "this path")
    args, _ = ap.parse_known_args()

    known = {"rtpm", "als", "trl", "kron", "contract", "grad_compress",
             "opt_state", "serve"}
    only = {s.strip() for s in args.only.split(",") if s.strip()}
    unknown = only - known
    if unknown:   # a typo must not silently produce an empty artifact
        raise SystemExit(f"--only: unknown benches {sorted(unknown)}; "
                         f"known: {sorted(known)}")
    want = lambda n: not only or n in only

    print("name,us_per_call,derived")
    t0 = time.time()

    from benchmarks import (bench_als, bench_contract, bench_grad_compress,
                            bench_kron, bench_opt_state, bench_rtpm,
                            bench_serve, bench_trl)

    if args.fast:
        if want("rtpm"):
            bench_rtpm.run(I=40, Js=(400,), table2=False)
        if want("als"):
            bench_als.run(I=40, Js=(800,), D=4, iters=8)
        if want("trl"):
            bench_trl.run(crs=(20, 100), n_train=512, n_test=256)
        if want("kron"):
            bench_kron.run(crs=(4, 16), D=8)
        if want("contract"):
            bench_contract.run(crs=(4, 16), D=8)
        if want("grad_compress"):
            bench_grad_compress.run(dims=1 << 18, ratios=(16,))
        if want("opt_state"):
            bench_opt_state.run(dims=(1 << 17, 1 << 13), ratios=(4,),
                                steps=10)
        if want("serve"):
            # hit_suffix must exceed prefill_bucket (32) so the
            # prefill_hit row really times the multi-bucket chunked path
            bench_serve.run(archs=("gemma-2b", "xlstm-1.3b"),
                            n_requests=8, max_new=4, max_batch=2,
                            hit_suffix=40, spec_max_new=32,
                            metrics_jsonl=args.metrics_jsonl or None)
    else:
        if want("rtpm"):
            bench_rtpm.run()
        if want("als"):
            bench_als.run()
        if want("trl"):
            bench_trl.run()
        if want("kron"):
            bench_kron.run()
        if want("contract"):
            bench_contract.run()
        if want("grad_compress"):
            bench_grad_compress.run()
        if want("opt_state"):
            bench_opt_state.run()
        if want("serve"):
            bench_serve.run(metrics_jsonl=args.metrics_jsonl or None)

    if args.json:
        _write_json(args.json)
    print(f"# total benchmark wall time: {time.time()-t0:.1f}s",
          file=sys.stderr)


if __name__ == "__main__":
    main()
