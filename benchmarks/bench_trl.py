"""Paper Table 4: sketched tensor-regression-layer (CP-TRL) classification
under varying compression ratios — CS vs TS vs FCS.

Paper setting: FMNIST, two conv+maxpool layers, activation (7,7,32),
C=10 classes.  Offline container => deterministic synthetic 10-class
dataset with the same activation tensor shape: class templates in a frozen
random conv feature space + noise (the comparison CS/TS/FCS at equal CR is
what the table is about; absolute accuracy differs from FMNIST).

The TRL weight tensor W (7,7,32,C) and activations X (B,7,7,32) are
sketched with the SAME per-mode hashes (J_n per mode) and the logits are
<sk(X), sk(W_c)> + b (Eq. 20/21); the sketched head is trained directly.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import fcs_sketch_len, make_tensor_hashes
from repro.core.sketches import fcs_general, ts_general

FEAT = (7, 7, 32)
C = 10


def _dataset(key, templates, n=2048, noise=4.0):
    """Synthetic 10-class data in the (7,7,32) feature space: shared class
    templates + per-example noise (noise 4.0 makes the dense problem
    non-trivial so compression differences show)."""
    kx, kl = jax.random.split(key)
    labels = jax.random.randint(kl, (n,), 0, C)
    x = templates[labels] + noise * jax.random.normal(kx, (n,) + FEAT)
    return x, labels


def _sketch_batch(X, hashes, kind):
    """X: (B,)+FEAT -> (B, J~) (D=1; batched via vmap over examples)."""
    f = {"fcs": fcs_general, "ts": ts_general}[kind]
    return jax.vmap(lambda x: f(x, hashes)[0])(X)


def _cs_batch(X, h, s, J):
    flat = X.reshape(X.shape[0], -1)
    onehot = (jax.nn.one_hot(h, J, dtype=flat.dtype)
              * s[:, None].astype(flat.dtype))
    return flat @ onehot


def _train_head(xs, labels, xs_test, labels_test, steps=300, lr=0.5):
    # standardize feature scale so one lr works across CRs/sketch kinds
    scale = jnp.sqrt(jnp.mean(xs ** 2) + 1e-9)
    xs = xs / scale
    xs_test = xs_test / scale
    Jt = xs.shape[-1]
    W = jnp.zeros((Jt, C))
    b = jnp.zeros((C,))

    @jax.jit
    def step(W, b):
        def loss_fn(W, b):
            logits = xs @ W + b
            lp = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(lp, labels[:, None], 1))
        g = jax.grad(loss_fn, argnums=(0, 1))(W, b)
        return W - lr * g[0], b - lr * g[1]

    for _ in range(steps):
        W, b = step(W, b)
    acc = float(jnp.mean(jnp.argmax(xs_test @ W + b, -1) == labels_test))
    return acc


def run(crs=(20, 40, 100), seed=0, n_train=2048, n_test=512):
    key = jax.random.PRNGKey(seed)
    # spatially smooth templates (cumulative sums over the two spatial
    # modes): FCS's selling point is preserving spatial structure, which
    # white-noise templates cannot exercise.
    raw = jax.random.normal(jax.random.fold_in(key, 99), (C,) + FEAT)
    templates = jnp.cumsum(jnp.cumsum(raw, axis=1), axis=2)
    templates = templates / jnp.sqrt(jnp.mean(templates ** 2))
    Xtr, ytr = _dataset(jax.random.fold_in(key, 0), templates, n_train)
    Xte, yte = _dataset(jax.random.fold_in(key, 1), templates, n_test)
    numel = FEAT[0] * FEAT[1] * FEAT[2]

    # dense baseline
    acc = _train_head(Xtr.reshape(n_train, -1), ytr,
                      Xte.reshape(n_test, -1), yte)
    emit("trl_table4/dense/cr1", 0.0, f"acc={acc:.4f}")

    for cr in crs:
        Jt_target = max(C + 2, numel // cr)
        # per-mode J for FCS/TS: sum J_n - N + 1 = Jt -> spread by mode size
        total = Jt_target + 2
        j1 = max(2, round(total * FEAT[0] / sum(FEAT)))
        j2 = max(2, round(total * FEAT[1] / sum(FEAT)))
        j3 = max(2, total - j1 - j2)
        hashes = make_tensor_hashes(jax.random.fold_in(key, 2),
                                    FEAT, (j1, j2, j3), 1)
        Jt = fcs_sketch_len((j1, j2, j3))
        for kind in ("fcs", "ts"):
            if kind == "ts":
                hs = make_tensor_hashes(jax.random.fold_in(key, 3),
                                        FEAT, Jt, 1)  # equal sketch length
                xs_tr = _sketch_batch(Xtr, hs, "ts")
                xs_te = _sketch_batch(Xte, hs, "ts")
            else:
                xs_tr = _sketch_batch(Xtr, hashes, "fcs")
                xs_te = _sketch_batch(Xte, hashes, "fcs")
            sec = timeit(lambda a=xs_tr: a, reps=1, warmup=0)
            acc = _train_head(xs_tr, ytr, xs_te, yte)
            emit(f"trl_table4/{kind}/cr{cr}", sec, f"acc={acc:.4f};Jt={Jt}")
        # CS baseline: one long hash pair over numel
        from repro.core import make_mode_hash
        mh = make_mode_hash(jax.random.fold_in(key, 4), numel, Jt, 1)
        xs_tr = _cs_batch(Xtr, mh.h[0], mh.s[0], Jt)
        xs_te = _cs_batch(Xte, mh.h[0], mh.s[0], Jt)
        acc = _train_head(xs_tr, ytr, xs_te, yte)
        emit(f"trl_table4/cs/cr{cr}", 0.0, f"acc={acc:.4f};Jt={Jt}")


def main():
    ap = argparse.ArgumentParser()
    ap.parse_args()
    run()


if __name__ == "__main__":
    main()
