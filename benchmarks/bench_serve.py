"""Continuous-batching serve benchmark: per-family tok/s, prefix-cache hit
rate, paged-KV reserved-vs-used bytes, chunked-prefill hit latency, and
speculative-decode speedup over mixed-length request streams with shared
system prefixes.

Attention families run at a big ``kv_max_seq`` to measure the paged pool:
the row reports peak RESERVED KV bytes (allocated blocks), peak USED KV
bytes ((S + max_new) rows of live requests), and the dense
max_batch * max_seq equivalent — the mixed-length stream must show a
>= 4x reserved-bytes reduction over the dense cache (asserted), since
reservations scale with allocated blocks, not engine geometry.

One row per served family — transformer (dense) vs recurrent (ssm /
hybrid) — so the slot scheduler's two state layouts are measured
separately, plus a ``prefill_hit`` row timing a cached-prefix request
whose uncached suffix spans multiple prefill buckets (the chunked-prefill
path) against the equivalent cold miss, and an ``async_stream`` row
driving the async front-end open-loop (Poisson arrivals, streamed
tokens, mid-stream cancellations) to report TTFT and p50/p99
inter-token latency with a zero-leaked-blocks assert at drain.

Reports steady-state decode throughput (compile excluded via a warmup
drain) and asserts the engine's contracts: one decode compilation for the
whole stream (and one chunked-prefill compilation for attention
families), and cached KV bytes never above the configured budget.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.registry import reduced_config
from repro.launch.serve import make_request_stream
from repro.models import model as M
from repro.serve.scheduler import KV_FAMILIES, Request, SlotScheduler


def _stream(arch: str, n_requests: int, n_prefixes: int, prefix_len: int,
            max_tail: int, max_new: int, max_batch: int, max_seq: int,
            sampled_frac: float) -> None:
    cfg = reduced_config(arch)
    k_params, _ = jax.random.split(jax.random.PRNGKey(0))
    params = M.init_params(k_params, cfg)
    serve = dataclasses.replace(
        cfg.serve, max_batch=max_batch, max_seq=max_seq,
        prefix_block=prefix_len, admit_threshold=2)
    sched = SlotScheduler(cfg, params, serve=serve)
    rng = np.random.RandomState(0)

    # recurrent families compile prefill per distinct prompt length, so
    # the compile warmup must cover EVERY length the stream can emit —
    # otherwise fresh compilations land inside the timed region and get
    # reported as family tok/s.  Attention families compile prefill once
    # (offset-traced chunks): the stream warmup below suffices.
    if cfg.family not in KV_FAMILIES:
        rng_w = np.random.RandomState(99)
        sched.run([Request(rid=20_000 + t,
                           tokens=rng_w.randint(
                               0, cfg.vocab_size,
                               (prefix_len + t,)).astype(np.int32),
                           max_new=max_new)
                   for t in range(1, max_tail + 1)])
    # stream warmup: lets the count-min tracker see the shared prefixes
    sched.run(make_request_stream(cfg, rng, max_batch, n_prefixes,
                                  prefix_len, max_tail, max_new,
                                  rid0=10_000, sampled_frac=sampled_frac))

    reqs = make_request_stream(cfg, rng, n_requests, n_prefixes, prefix_len,
                               max_tail, max_new,
                               sampled_frac=sampled_frac)
    t0 = time.time()
    done = sched.run(reqs)
    dt = time.time() - t0
    toks = sum(len(c.tokens) for c in done)
    assert sched.decode_compilations == 1, sched.decode_compilations
    derived = (f"family={cfg.family};tok_s={toks/dt:.1f};"
               f"decode_compiles={sched.decode_compilations};"
               f"decode_steps={sched.decode_steps};"
               f"prefill_compiles={sched.prefill_compilations}")
    if cfg.family in KV_FAMILIES:
        st = sched.prefix_cache.stats
        assert sched.prefill_compilations == 1, sched.prefill_compilations
        assert st.bytes <= serve.prefix_cache_bytes, (
            st.bytes, serve.prefix_cache_bytes)
        reserved = sched.kv_peak_reserved_bytes()
        used = sched.kv_peak_used_bytes()
        dense = sched.kv_dense_equiv_bytes()
        reduction = dense / max(reserved, 1)
        # the paged-pool contract: reservations scale with allocated
        # blocks, not max_batch * max_seq
        assert reduction >= 4.0, (reserved, dense)
        derived += (f";hit_rate={st.hit_rate:.2f};cached_bytes={st.bytes};"
                    f"budget={serve.prefix_cache_bytes};"
                    f"tracker_bytes={sched.prefix_cache.tracker_bytes()};"
                    f"kv_pool_bytes={sched.kv_cache_bytes()};"
                    f"kv_peak_reserved_bytes={reserved};"
                    f"kv_peak_used_bytes={used};"
                    f"kv_dense_equiv_bytes={dense};"
                    f"kv_reduction={reduction:.1f}")
    emit(f"serve/continuous_batch/{arch}", dt / max(toks, 1), derived)


def _speculative(arch: str, n_requests: int, prompt_len: int, max_new: int,
                 max_seq: int, spec_k: int, target_layers: int,
                 draft_depth: int) -> None:
    """Speculative vs plain greedy decode on the dense family.

    Measures the MECHANICS of the speculative path at a controlled
    acceptance rate: the target is a ``target_layers``-deep reduced
    model whose layers above ``draft_depth`` have their residual outputs
    zeroed, so the truncated draft agrees with the target and acceptance
    sits near the ceiling (a trained draft's acceptance is a model
    property this random-init bench can't measure).  Reports
    accepted-tokens/s for both paths, the speedup, the acceptance rate
    and the mean accepted-run length; asserts the speculative stream is
    faster and token-for-token identical to plain greedy decode.
    """
    cfg = dataclasses.replace(reduced_config(arch),
                              num_layers=target_layers)
    k_params, _ = jax.random.split(jax.random.PRNGKey(0))
    params = M.init_params(k_params, cfg)
    params["blocks"]["attn"]["wo"] = \
        params["blocks"]["attn"]["wo"].at[draft_depth:].set(0)
    params["blocks"]["ffn"]["w_down"] = \
        params["blocks"]["ffn"]["w_down"].at[draft_depth:].set(0)
    rng = np.random.RandomState(3)
    prompts = [rng.randint(0, cfg.vocab_size,
                           (prompt_len,)).astype(np.int32)
               for _ in range(n_requests)]

    def run(k):
        serve = dataclasses.replace(
            cfg.serve, max_batch=4, max_seq=max_seq, prefill_bucket=16,
            admit_threshold=1 << 30, spec_k=k, draft_depth=draft_depth)
        sched = SlotScheduler(cfg, params, serve=serve)
        # compile warmup: fill every slot once
        sched.run([Request(rid=10_000 + i, tokens=p, max_new=max_new)
                   for i, p in enumerate(prompts[:4])])
        reqs = [Request(rid=i, tokens=p, max_new=max_new)
                for i, p in enumerate(prompts)]
        t0 = time.time()
        done = sched.run(reqs)
        dt = time.time() - t0
        toks = sum(len(c.tokens) for c in done)
        assert sched.decode_compilations == 1, sched.decode_compilations
        return toks / dt, sched, {c.rid: c.tokens for c in done}

    plain_tok_s, _, ref = run(0)
    spec_tok_s, sched, out = run(spec_k)
    for rid, toks in ref.items():
        np.testing.assert_array_equal(
            out[rid], toks,
            err_msg=f"speculative greedy diverged from plain (rid {rid})")
    speedup = spec_tok_s / plain_tok_s
    # the latency win the paged pool + verify step were built for: at
    # spec_k >= 4 with a healthy acceptance rate the speculative stream
    # must beat plain decode on accepted-tokens/s
    assert speedup > 1.0, (spec_tok_s, plain_tok_s)
    emit(f"serve/speculative/{arch}", 1.0 / spec_tok_s,
         f"family={cfg.family};spec_k={spec_k};draft_depth={draft_depth};"
         f"target_layers={target_layers};spec_tok_s={spec_tok_s:.1f};"
         f"plain_tok_s={plain_tok_s:.1f};spec_speedup={speedup:.2f}x;"
         f"accept_rate={sched.acceptance_rate:.2f};"
         f"mean_accepted_run={sched.mean_accepted_run:.2f}")


def _long_context(arch: str, context: int, max_new: int, max_seq: int,
                  window: int, ratio: int, num_blocks: int) -> None:
    """Sketched long-context serve: one prompt of ``context`` tokens
    decoded through a pool of ``num_blocks`` blocks — the context is
    >= 4x the pool's row capacity (asserted), which the exact paged path
    cannot serve at all.  Reports steady-state tok/s, the exact-window /
    sketched-tail / dense-equivalent byte split, and the tail span's
    cosine fidelity against a full-context oracle at the bench geometry
    (same fold + query math the engine compiles, on known random rows).
    """
    from repro.serve import kv_sketch as kvs

    cfg = reduced_config(arch)
    k_params, _ = jax.random.split(jax.random.PRNGKey(0))
    params = M.init_params(k_params, cfg)
    bs = cfg.serve.kv_block_size
    serve = dataclasses.replace(
        cfg.serve, max_batch=1, max_seq=max_seq, num_kv_blocks=num_blocks,
        admit_threshold=1 << 30, kv_sketch_window=window,
        kv_sketch_ratio=ratio)
    sched = SlotScheduler(cfg, params, serve=serve)
    pool_rows = num_blocks * bs
    assert context >= 4 * pool_rows, (context, pool_rows)
    rng = np.random.RandomState(5)
    prompt = rng.randint(0, cfg.vocab_size, (context,)).astype(np.int32)
    # compile warmup (prefill chunks + fold + decode chunk)
    sched.run([Request(rid=10_000, tokens=prompt, max_new=max_new)])
    t0 = time.time()
    done = sched.run([Request(rid=0, tokens=prompt, max_new=max_new)])
    dt = time.time() - t0
    toks = sum(len(c.tokens) for c in done)
    assert toks == max_new, toks
    assert sched.decode_compilations == 1, sched.decode_compilations
    tail_b = sched.kv_sketch_tail_bytes()
    reserved = sched.kv_peak_reserved_bytes()
    dense = sched.kv_dense_equiv_bytes()

    # tail fidelity at this geometry: fold known random rows, query the
    # sketch, cosine against the exact softmax over the same rows
    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    R = cfg.num_heads // K
    Tf = context - window                       # the folded span
    coeffs = kvs.tail_coeffs(serve)
    C = kvs.tail_cols(max_seq, ratio)
    dom = kvs.pos_domain(max_seq, bs)
    onehot = kvs.pos_onehot(coeffs, dom, C)
    kr = jnp.asarray(rng.randn(1, Tf, K, hd).astype(np.float32))
    vr = jnp.asarray(rng.randn(1, Tf, K, hd).astype(np.float32))
    q = jnp.asarray(rng.randn(1, 1, K, R, hd).astype(np.float32))
    tail = kvs.fold_rows(kr, vr, jnp.arange(Tf, dtype=jnp.int32), coeffs, C)
    fb = jnp.asarray([Tf], jnp.int32)
    scale = 1.0 / float(np.sqrt(hd))
    _, l_t, acc_t = kvs.tail_attend(q, tail["k"], tail["v"], onehot, fb,
                                    scale)
    _, l_o, acc_o = kvs.dense_tail_stats(q, kr, vr, fb, scale)
    out_t = (acc_t / jnp.maximum(l_t, 1e-30)[..., None]).reshape(-1)
    out_o = (acc_o / jnp.maximum(l_o, 1e-30)[..., None]).reshape(-1)
    cos = float(jnp.vdot(out_t, out_o)
                / jnp.maximum(jnp.linalg.norm(out_t)
                              * jnp.linalg.norm(out_o), 1e-30))
    emit(f"serve/long_context/{arch}", dt / max(toks, 1),
         f"family={cfg.family};context={context};window={window};"
         f"ratio={ratio};pool_rows={pool_rows};tok_s={toks/dt:.1f};"
         f"kv_peak_reserved_bytes={reserved};kv_tail_bytes={tail_b};"
         f"kv_dense_equiv_bytes={dense};"
         f"kv_reduction={dense / max(reserved + tail_b, 1):.1f};"
         f"tail_cosine={cos:.3f};"
         f"decode_compiles={sched.decode_compilations}")


def _paged_kernel(arch: str, n_requests: int, prompt_len: int,
                  max_new: int, max_seq: int) -> None:
    """Flash-decode paged-attention kernel vs the jnp gather path.

    Runs the same greedy stream through two engines — one with
    ``paged_kernels=True`` (Pallas; interpret mode off-TPU) and one with
    the jnp oracle path — and times one compiled multi-query verify step
    on each implementation over an identically prefilled pool.  Reports
    decode tok/s for both, the kernel/jnp speedup, and the verify-step
    latencies.  No speedup floor is asserted: on CPU the kernel runs
    interpreted, so the ratio only becomes a win on TPU — the row exists
    to put the number on the trend line either way.
    """
    import jax.numpy as jnp

    from repro.models import transformer as tf

    cfg = reduced_config(arch)
    k_params, _ = jax.random.split(jax.random.PRNGKey(0))
    params = M.init_params(k_params, cfg)
    rng = np.random.RandomState(7)
    prompts = [rng.randint(0, cfg.vocab_size,
                           (prompt_len,)).astype(np.int32)
               for _ in range(n_requests)]

    def run_engine(pk):
        serve = dataclasses.replace(
            cfg.serve, max_batch=2, max_seq=max_seq, decode_chunk=4,
            prefill_bucket=16, admit_threshold=1 << 30, paged_kernels=pk)
        sched = SlotScheduler(cfg, params, serve=serve)
        sched.run([Request(rid=10_000 + i, tokens=p, max_new=max_new)
                   for i, p in enumerate(prompts[:2])])   # compile warmup
        reqs = [Request(rid=i, tokens=p, max_new=max_new)
                for i, p in enumerate(prompts)]
        t0 = time.time()
        done = sched.run(reqs)
        dt = time.time() - t0
        assert sched.decode_compilations == 1, sched.decode_compilations
        return sum(len(c.tokens) for c in done) / dt

    kernel_tok_s = run_engine(True)
    jnp_tok_s = run_engine(False)

    # one compiled verify step (spec_max + 1 = 4 rows/slot), same cache
    from benchmarks.common import timeit
    B, bs, nper = 2, 16, max_seq // 16
    tables = jnp.arange(B * nper, dtype=jnp.int32).reshape(B, nper)
    cache = tf.init_paged_cache(cfg, B * nper, bs)
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (1, 16)), jnp.int32)
    for b in range(B):
        cache = tf.prefill_chunk(params, cache, toks, tables[b],
                                 jnp.int32(0), cfg, kernels=False)
    vt = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, 4)), jnp.int32)
    pos = jnp.full((B,), 16, jnp.int32)

    def verify_fn(pk):
        return jax.jit(lambda c, t, i: tf.verify_step(
            params, c, t, i, cfg, tables=tables, kernels=pk)[0])

    t_k = timeit(verify_fn(True), cache, vt, pos)
    t_j = timeit(verify_fn(False), cache, vt, pos)
    emit(f"serve/paged_kernel/{arch}", 1.0 / max(kernel_tok_s, 1e-9),
         f"family={cfg.family};kernel_tok_s={kernel_tok_s:.1f};"
         f"jnp_tok_s={jnp_tok_s:.1f};"
         f"paged_kernel_speedup={kernel_tok_s / jnp_tok_s:.2f}x;"
         f"verify_us_kernel={t_k * 1e6:.1f};"
         f"verify_us_jnp={t_j * 1e6:.1f};"
         f"backend={jax.default_backend()}")


def _async_stream(arch: str, n_requests: int, n_prefixes: int,
                  prefix_len: int, max_tail: int, max_new: int,
                  max_batch: int, max_seq: int, rate: float,
                  cancel_frac: float) -> None:
    """Open-loop async serving latency: Poisson arrivals through the
    ``AsyncServeEngine`` pump, tokens streamed per decode chunk, a
    fraction of clients hanging up mid-stream.

    Reports time-to-first-token and p50/p99 inter-token latency (per
    streamed token, wall clock — tokens inside one delivered chunk are
    near-zero apart, the p99 is the chunk cadence) alongside tok/s.
    Asserts the pump's contracts: still ONE decode compilation across
    admission / cancellation / drain, at least one cancellation actually
    landed mid-flight, and ZERO leaked pool blocks at drain — every
    reserved block is accounted to the prefix cache once all slots
    retire."""
    import asyncio

    from repro.serve.frontend import AsyncServeEngine

    cfg = reduced_config(arch)
    k_params, _ = jax.random.split(jax.random.PRNGKey(0))
    params = M.init_params(k_params, cfg)
    serve = dataclasses.replace(
        cfg.serve, max_batch=max_batch, max_seq=max_seq,
        prefix_block=prefix_len, admit_threshold=2)
    sched = SlotScheduler(cfg, params, serve=serve)
    rng = np.random.RandomState(0)
    # compile warmup (closed batch) — the async pump runs the same chunk
    sched.run(make_request_stream(cfg, rng, max_batch, n_prefixes,
                                  prefix_len, max_tail, max_new,
                                  rid0=10_000))
    # greedy stream: cancellation must land between chunk deliveries, so
    # budgets span several decode chunks
    assert max_new > 2 * serve.decode_chunk, (max_new, serve.decode_chunk)
    reqs = make_request_stream(cfg, rng, n_requests, n_prefixes,
                               prefix_len, max_tail, max_new)
    front = AsyncServeEngine(scheduler=sched)
    rng_arr = np.random.RandomState(11)

    async def go():
        ttfts, itls, done = [], [], []

        async def consume(handle, t_submit, cancel_after):
            n, prev = 0, 0.0
            async for _tok in handle.stream():
                now = time.monotonic()
                if n == 0:
                    ttfts.append(now - t_submit)
                else:
                    itls.append(now - prev)
                prev = now
                n += 1
                if cancel_after is not None and n >= cancel_after:
                    handle.cancel()
            done.append(handle.completion)

        tasks = []
        for r in reqs:
            h = await front.submit(r.tokens, max_new=r.max_new, rid=r.rid)
            cancel_after = (max(1, r.max_new // 2)
                            if rng_arr.rand() < cancel_frac else None)
            tasks.append(asyncio.ensure_future(
                consume(h, time.monotonic(), cancel_after)))
            await asyncio.sleep(float(rng_arr.exponential(1.0 / rate)))
        await asyncio.gather(*tasks)
        await front.drain()
        return ttfts, itls, done

    t0 = time.time()
    ttfts, itls, done = asyncio.run(go())
    dt = time.time() - t0
    toks = sum(len(c.tokens) for c in done)
    st = sched.stats()
    assert st.decode_compilations == 1, st.decode_compilations
    n_cancel = sum(1 for c in done if c.status == "cancelled")
    assert n_cancel >= 1, "no cancellation landed mid-flight"
    assert toks < n_requests * max_new, "cancelled clients got full budgets"
    # zero-leak contract: with every slot retired, reserved pool blocks
    # are exactly the prefix cache's holdings (free + held == pool)
    held = sched.prefix_cache.held_blocks()
    leaked = sched.num_blocks - sched.alloc.free_count - held
    assert leaked == 0, (sched.alloc.free_count, held, sched.num_blocks)
    emit(f"serve/async_stream/{arch}", dt / max(toks, 1),
         f"family={cfg.family};arrival_rate={rate};tok_s={toks/dt:.1f};"
         f"ttft_p50_ms={np.percentile(ttfts, 50)*1e3:.1f};"
         f"ttft_p99_ms={np.percentile(ttfts, 99)*1e3:.1f};"
         f"itl_p50_ms={np.percentile(itls, 50)*1e3:.2f};"
         f"itl_p99_ms={np.percentile(itls, 99)*1e3:.1f};"
         f"cancelled={n_cancel};served={len(done)};"
         f"blocks_leaked={leaked};"
         f"decode_compiles={st.decode_compilations}")


def _obs_overhead(arch: str, n_requests: int, n_prefixes: int,
                  prefix_len: int, max_tail: int, max_new: int,
                  max_batch: int, max_seq: int,
                  metrics_jsonl=None) -> None:
    """Observability overhead: the same closed-batch greedy stream
    through a bare engine and one carrying a full ``ServeObserver``
    (tracing at sample_rate=1, metrics flushed EVERY decode round —
    the worst case; the probe needs kv sketching and is off here).

    The primary ``us_per_call`` is the tracing-OFF run, so the spread
    gate in compare.py keeps guarding baseline serve throughput; the
    tracing-on ratio is reported (and bounded) separately.  Asserts the
    two runs' tokens are bitwise identical and each compiled the decode
    chunk exactly once — observability must never touch the compiled
    path."""
    from repro.obs import ServeObserver, Tracer

    cfg = reduced_config(arch)
    k_params, _ = jax.random.split(jax.random.PRNGKey(0))
    params = M.init_params(k_params, cfg)

    def run_once(obs):
        serve = dataclasses.replace(
            cfg.serve, max_batch=max_batch, max_seq=max_seq,
            prefix_block=prefix_len, admit_threshold=2)
        sched = SlotScheduler(cfg, params, serve=serve, obs=obs)
        rng = np.random.RandomState(0)
        # compile warmup with the observer already attached: hooks run
        # host-side only, so the compiled chunk is identical either way
        sched.run(make_request_stream(cfg, rng, max_batch, n_prefixes,
                                      prefix_len, max_tail, max_new,
                                      rid0=10_000))
        reqs = make_request_stream(cfg, rng, n_requests, n_prefixes,
                                   prefix_len, max_tail, max_new)
        t0 = time.time()
        done = sched.run(reqs)
        dt = time.time() - t0
        assert sched.decode_compilations == 1, sched.decode_compilations
        return dt, sum(len(c.tokens) for c in done), \
            {c.rid: np.asarray(c.tokens) for c in done}

    t_off, toks_off, out_off = run_once(None)
    obs = ServeObserver(tracer=Tracer(sample_rate=1.0),
                        metrics_path=metrics_jsonl,
                        metrics_interval=0.0)
    t_on, toks_on, out_on = run_once(obs)
    obs.close()
    for rid, ref in out_off.items():
        np.testing.assert_array_equal(
            out_on[rid], ref,
            err_msg=f"observer changed greedy tokens (rid {rid})")
    ratio = t_on / t_off
    # host-side hooks on a pump that blocks on a device chunk per round:
    # a 1.5x wall-clock ceiling is generous — regressions that sneak a
    # sync or per-token work into the hooks blow well past it
    assert ratio <= 1.5, (t_on, t_off)
    assert len(obs.tracer) > 0 and len(obs.windows) > 0
    emit(f"serve/obs_overhead/{arch}", t_off / max(toks_off, 1),
         f"family={cfg.family};tok_s={toks_off/t_off:.1f};"
         f"tok_s_on={toks_on/t_on:.1f};obs_overhead={ratio:.3f};"
         f"trace_events={len(obs.tracer)};windows={len(obs.windows)}")


def _hit_latency(arch: str, prefix_len: int, suffix_len: int, max_new: int,
                 max_seq: int) -> None:
    """Cached-prefix request latency (suffix chunk-prefilled, spanning
    multiple buckets) vs the equivalent cold miss."""
    cfg = reduced_config(arch)
    k_params, _ = jax.random.split(jax.random.PRNGKey(0))
    params = M.init_params(k_params, cfg)
    serve = dataclasses.replace(
        cfg.serve, max_batch=1, max_seq=max_seq, prefix_block=prefix_len,
        admit_threshold=2)
    sched = SlotScheduler(cfg, params, serve=serve)
    rng = np.random.RandomState(1)
    prefix = rng.randint(0, cfg.vocab_size, (prefix_len,)).astype(np.int32)

    def req(rid):
        tail = rng.randint(0, cfg.vocab_size, (suffix_len,)).astype(np.int32)
        return Request(rid=rid, tokens=np.concatenate([prefix, tail]),
                       max_new=max_new)

    # warm: compile + push the shared prefix over the admission threshold
    for i in range(3):
        sched.run([req(i)])
    t0 = time.time()
    hit = sched.run([req(100)])[0]
    t_hit = time.time() - t0
    assert hit.prefix_hit, "prefix should be cached by now"
    t0 = time.time()
    cold = sched.run([Request(
        rid=101,
        tokens=rng.randint(0, cfg.vocab_size,
                           (prefix_len + suffix_len,)).astype(np.int32),
        max_new=max_new)])[0]
    t_cold = time.time() - t0
    assert not cold.prefix_hit
    n_buckets = -(-suffix_len // cfg.serve.prefill_bucket)
    emit(f"serve/prefill_hit/{arch}", t_hit,
         f"cold_miss_s={t_cold:.4f};speedup={t_cold/max(t_hit,1e-9):.2f}x;"
         f"suffix_tokens={suffix_len};suffix_buckets={n_buckets};"
         f"decode_compiles={sched.decode_compilations}")


def run(archs=("gemma-2b", "xlstm-1.3b", "zamba2-2.7b"),
        n_requests: int = 24, n_prefixes: int = 3, prefix_len: int = 32,
        max_tail: int = 12, max_new: int = 8, max_batch: int = 4,
        max_seq: int = 128, kv_max_seq: int = 512,
        sampled_frac: float = 0.25, hit_suffix: int = 48,
        spec_k: int = 4, spec_max_new: int = 48,
        metrics_jsonl=None) -> None:
    for arch in archs:
        # attention families get the big-max_seq geometry: the paged pool
        # makes sequence capacity nearly free (blocks are reserved per
        # request), while recurrent families still preallocate dense
        # per-slot state and stay at the small max_seq
        fam_seq = (kv_max_seq if reduced_config(arch).family in KV_FAMILIES
                   else max_seq)
        _stream(arch, n_requests, n_prefixes, prefix_len, max_tail,
                max_new, max_batch, fam_seq, sampled_frac)
    # open-loop async serving: Poisson arrivals, streamed tokens, mid-
    # stream hangups; TTFT + inter-token latency + zero-leak at drain
    _async_stream("gemma-2b", n_requests=12, n_prefixes=n_prefixes,
                  prefix_len=prefix_len, max_tail=max_tail, max_new=24,
                  max_batch=max_batch, max_seq=kv_max_seq, rate=50.0,
                  cancel_frac=0.5)
    # observability overhead: identical greedy stream, observer on/off;
    # tracing-off tok/s is the gated number
    _obs_overhead("gemma-2b", n_requests=min(n_requests, 12),
                  n_prefixes=n_prefixes, prefix_len=prefix_len,
                  max_tail=max_tail, max_new=max_new, max_batch=max_batch,
                  max_seq=kv_max_seq, metrics_jsonl=metrics_jsonl)
    # chunked-prefill hit latency: suffix spans multiple prefill buckets
    _hit_latency("gemma-2b", prefix_len=prefix_len, suffix_len=hit_suffix,
                 max_new=max_new, max_seq=max_seq)
    # speculative decode: dense family, acceptance-ceiling draft
    _speculative("gemma-2b", n_requests=8, prompt_len=16,
                 max_new=spec_max_new, max_seq=kv_max_seq, spec_k=spec_k,
                 target_layers=6, draft_depth=1)
    # sketched long-context: context >= 4x the pool's row capacity
    _long_context("gemma-2b", context=580, max_new=max_new, max_seq=1024,
                  window=64, ratio=8, num_blocks=9)
    # flash-decode paged-attention kernel vs the jnp gather path
    _paged_kernel("gemma-2b", n_requests=4, prompt_len=12,
                  max_new=max_new, max_seq=64)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
