"""Continuous-batching serve benchmark: tok/s and prefix-cache hit rate
over a mixed-length request stream with shared system prefixes.

Reports steady-state decode throughput (compile excluded via a warmup
drain), the prefix-cache hit rate / cached bytes vs budget, and asserts
the engine's two contracts: one decode compilation for the whole stream,
and cached KV bytes never above the configured budget.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.registry import reduced_config
from repro.launch.serve import make_request_stream
from repro.models import model as M
from repro.serve.scheduler import SlotScheduler


def run(arch: str = "gemma-2b", n_requests: int = 24, n_prefixes: int = 3,
        prefix_len: int = 32, max_tail: int = 12, max_new: int = 8,
        max_batch: int = 4, max_seq: int = 128) -> None:
    cfg = reduced_config(arch)
    k_params, _ = jax.random.split(jax.random.PRNGKey(0))
    params = M.init_params(k_params, cfg)
    serve = dataclasses.replace(
        cfg.serve, max_batch=max_batch, max_seq=max_seq,
        prefix_block=prefix_len, admit_threshold=2)
    sched = SlotScheduler(cfg, params, serve=serve)
    rng = np.random.RandomState(0)

    # warmup drain: compiles decode once + the prefill buckets
    sched.run(make_request_stream(cfg, rng, max_batch, n_prefixes,
                                  prefix_len, max_tail, max_new,
                                  rid0=10_000))

    reqs = make_request_stream(cfg, rng, n_requests, n_prefixes, prefix_len,
                               max_tail, max_new)
    t0 = time.time()
    done = sched.run(reqs)
    dt = time.time() - t0
    toks = sum(len(c.tokens) for c in done)
    st = sched.prefix_cache.stats
    assert sched.decode_compilations == 1, sched.decode_compilations
    assert st.bytes <= serve.prefix_cache_bytes, (st.bytes,
                                                  serve.prefix_cache_bytes)
    emit(f"serve/continuous_batch/{arch}", dt / max(toks, 1),
         f"tok_s={toks/dt:.1f};hit_rate={st.hit_rate:.2f};"
         f"cached_bytes={st.bytes};budget={serve.prefix_cache_bytes};"
         f"tracker_bytes={sched.prefix_cache.tracker_bytes()};"
         f"decode_compiles={sched.decode_compilations};"
         f"decode_steps={sched.decode_steps}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
