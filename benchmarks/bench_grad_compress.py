"""Beyond-paper: FCS gradient compression — ratio vs reconstruction error
vs error-feedback convergence (the framework-integration benchmark)."""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.train.grad_compress import (_leaf_codecs, compress_roundtrip,
                                       sketch_leaf, unsketch_leaf)


def run(dims=1 << 20, ratios=(8, 16, 64), seed=0):
    g = jax.random.normal(jax.random.PRNGKey(seed), (dims,))
    for r in ratios:
        _, flat = _leaf_codecs({"g": g}, ratio=r, seed=seed)
        c = flat[0]
        key = jax.random.PRNGKey(0)
        f_sk = jax.jit(lambda x: sketch_leaf(x, c, key))
        sec = timeit(f_sk, g)
        sk = f_sk(g)
        ghat = unsketch_leaf(sk, c, g.shape, jnp.float32, key)
        err = float(jnp.linalg.norm(ghat - g) / jnp.linalg.norm(g))
        emit(f"grad_compress/sketch/r{r}", sec,
             f"rel_err={err:.4f};bytes={sk.size*4};orig={g.size*4}")

        # unbiased compressed-SGD convergence on a quadratic
        target = jax.random.normal(jax.random.PRNGKey(1), (dims,))
        x = jnp.zeros_like(target)

        @jax.jit
        def step(x, t):
            grad = x - target
            gh, _ = compress_roundtrip(grad, jnp.zeros((1,)), c,
                                       jax.random.PRNGKey(t))
            return x - (0.5 / r) * gh
        for t in range(30 * r):
            x = step(x, t)
        rel = float(jnp.linalg.norm(x - target) / jnp.linalg.norm(target))
        emit(f"grad_compress/sgd_30r/r{r}", 0.0, f"rel_err={rel:.4f}")


def main():
    argparse.ArgumentParser().parse_args()
    run()


if __name__ == "__main__":
    main()
