"""Paper Table 3: (sketched) ALS on a synthetic asymmetric CP rank-10
tensor — plain vs TS vs FCS, residual + running time.

Container scaling: I=80 instead of 400 (the 400^3 tensor alone is 256 MB
and the plain MTTKRP is ~40 GFLOP/iter — out of 1-core budget); the
J/I and noise regime matches the paper's.  --paper-size restores 400.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.cpd.als import als_decompose, als_residual


def run(I=80, R=10, sigma=0.01, Js=(1500, 3000), D=10, iters=30, seed=0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    A0 = jnp.linalg.qr(jax.random.normal(ks[0], (I, I)))[0][:, :R]
    B0 = jnp.linalg.qr(jax.random.normal(ks[1], (I, I)))[0][:, :R]
    C0 = jnp.linalg.qr(jax.random.normal(ks[2], (I, I)))[0][:, :R]
    Tc = jnp.einsum("ar,br,cr->abc", A0, B0, C0)
    Tn = Tc + sigma * jax.random.normal(key, (I, I, I))
    nC = jnp.linalg.norm(Tc)

    def once(method, J):
        lam, F = als_decompose(Tn, R, jax.random.PRNGKey(2), method=method,
                               hash_len=J, n_sketches=D, n_iters=iters)
        r_obs = float(als_residual(Tn, lam, F))
        A, B, C = F
        r_clean = float(jnp.linalg.norm(
            Tc - jnp.einsum("r,ar,br,cr->abc", lam, A, B, C)) / nC)
        return r_obs, r_clean

    sec = timeit(lambda: once("plain", 0), reps=1, warmup=0)
    r_obs, r_clean = once("plain", 0)
    emit("als_table3/plain", sec,
         f"res_obs={r_obs:.4f};res_clean={r_clean:.4f}")
    for method in ("ts", "fcs"):
        for J in Js:
            sec = timeit(lambda m=method, j=J: once(m, j), reps=1, warmup=0)
            r_obs, r_clean = once(method, J)
            emit(f"als_table3/{method}/J{J}/D{D}", sec,
                 f"res_obs={r_obs:.4f};res_clean={r_clean:.4f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper-size", action="store_true")
    args = ap.parse_args()
    if args.paper_size:
        run(I=400, Js=(3000, 5000, 7000), D=10)
    else:
        run()


if __name__ == "__main__":
    main()
