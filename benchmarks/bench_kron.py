"""Paper Fig. 5: Kronecker-product compression — CS vs HCS vs FCS:
compress time, decompress time, relative error, hash memory, across CRs.

Exact paper sizes: A (30,40), B (40,50) uniform [-5,5]; D=20.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_once
from repro.core import (
    cs_apply, cs_unsketch, fcs_kron_compress, fcs_kron_decompress,
    fcs_sketch_len, make_mode_hash, make_tensor_hashes,
    storage_bytes_cs_long, storage_bytes_tabulated,
)
from repro.core.sketches import hcs_general

SHA, SHB = (30, 40), (40, 50)


def _hcs_kron(A, B, hashes):
    """HCS of A (x) B via the outer-product structure (Shi 2019)."""
    skA = hcs_general(A, hashes[:2])            # (D, J1, J2)
    skB = hcs_general(B, hashes[2:])            # (D, J3, J4)
    return jnp.einsum("dab,dce->dabce", skA, skB)


def _hcs_kron_decompress(sk, hashes, shapeA, shapeB):
    mh = hashes
    I1, I2 = shapeA
    I3, I4 = shapeB

    def one(d):
        g = sk[d][mh[0].h[d][:, None, None, None],
                  mh[1].h[d][None, :, None, None],
                  mh[2].h[d][None, None, :, None],
                  mh[3].h[d][None, None, None, :]]
        sign = (mh[0].s[d][:, None, None, None]
                * mh[1].s[d][None, :, None, None]
                * mh[2].s[d][None, None, :, None]
                * mh[3].s[d][None, None, None, :])
        return sign * g
    est = jnp.median(jax.lax.map(one, jnp.arange(mh[0].D)), axis=0)
    return est.transpose(0, 2, 1, 3).reshape(I1 * I3, I2 * I4)


def run(crs=(2, 4, 8, 16), D=20, seed=0):
    key = jax.random.PRNGKey(seed)
    kA, kB = jax.random.split(key)
    A = jax.random.uniform(kA, SHA, minval=-5.0, maxval=5.0)
    B = jax.random.uniform(kB, SHB, minval=-5.0, maxval=5.0)
    K = jnp.kron(A, B)
    numel = K.size
    dims = SHA + SHB

    for cr in crs:
        Jt = max(8, numel // cr)
        J = max(2, (Jt + 3) // 4)               # per-mode (4J - 3 = Jt)
        Jt = fcs_sketch_len([J] * 4)
        # FCS
        hashes = make_tensor_hashes(jax.random.fold_in(key, cr), dims, J, D)
        f_c = jax.jit(lambda a, b: fcs_kron_compress(a, b, hashes))
        sec_c, sk = time_once(f_c, A, B)
        f_d = jax.jit(lambda s: fcs_kron_decompress(s, hashes, SHA, SHB))
        sec_d, Khat = time_once(f_d, sk)
        err = float(jnp.linalg.norm(Khat - K) / jnp.linalg.norm(K))
        mem = storage_bytes_tabulated(hashes)
        emit(f"kron_fig5/fcs/cr{cr}", sec_c,
             f"decomp_us={sec_d*1e6:.0f};rel_err={err:.4f};hash_bytes={mem}")
        # HCS at matched sketched dim: J_h^4 ~= Jt
        Jh = max(2, round(Jt ** 0.25))
        hh = make_tensor_hashes(jax.random.fold_in(key, cr + 100), dims,
                                Jh, D)
        h_c = jax.jit(lambda a, b: _hcs_kron(a, b, hh))
        sec_c, skh = time_once(h_c, A, B)
        h_d = jax.jit(lambda s: _hcs_kron_decompress(s, hh, SHA, SHB))
        sec_d, Kh2 = time_once(h_d, skh)
        err = float(jnp.linalg.norm(Kh2 - K) / jnp.linalg.norm(K))
        emit(f"kron_fig5/hcs/cr{cr}", sec_c,
             f"decomp_us={sec_d*1e6:.0f};rel_err={err:.4f};"
             f"hash_bytes={storage_bytes_tabulated(hh)}")
        # CS on the materialized Kronecker product (long hash pair)
        mh = make_mode_hash(jax.random.fold_in(key, cr + 200), numel, Jt, D)
        c_c = jax.jit(lambda a, b: cs_apply(jnp.kron(a, b).reshape(-1), mh))
        sec_c, skc = time_once(c_c, A, B)
        c_d = jax.jit(lambda s: cs_unsketch(s, mh))
        sec_d, Kc = time_once(c_d, skc)
        err = float(jnp.linalg.norm(Kc.reshape(K.shape) - K)
                    / jnp.linalg.norm(K))
        emit(f"kron_fig5/cs/cr{cr}", sec_c,
             f"decomp_us={sec_d*1e6:.0f};rel_err={err:.4f};"
             f"hash_bytes={storage_bytes_cs_long(dims, D)}")


def main():
    argparse.ArgumentParser().parse_args()
    run()


if __name__ == "__main__":
    main()
