"""Paper Fig. 1 + Table 2: (sketched) RTPM on synthetic symmetric CP
tensors.

Fig. 1 setting: symmetric rank-10, orthonormal factors, sigma=0.01,
D=2, L=15, T=20 — plain vs CS vs TS vs FCS across hash lengths.
Table 2 setting: I=50, HCS vs FCS at matched sketched dimension
(J_hcs^3 ~= 3*J_fcs - 2), D in {10, 15, 20}.

Container scaling: I=60 instead of 100 and trimmed hash grids (1-core CPU);
flags restore paper sizes.  Both residual metrics are reported: vs the
observed (noisy) tensor — whose floor is ||E||/||T|| — and vs the clean
low-rank tensor (factor-recovery quality).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.cpd.rtpm import cp_reconstruct, rtpm_decompose


def run(I=60, R=10, sigma=0.01, Js=(600, 1200), D=10, L=15, T=20,
        methods=("plain", "ts", "fcs"), table2=True, seed=0):
    key = jax.random.PRNGKey(seed)
    Q, _ = jnp.linalg.qr(jax.random.normal(key, (I, I)))
    U = Q[:, :R]
    Tc = jnp.einsum("ar,br,cr->abc", U, U, U)
    Tn = Tc + sigma * jax.random.normal(key, (I, I, I))
    nT, nC = jnp.linalg.norm(Tn), jnp.linalg.norm(Tc)

    def once(method, J, Dn):
        lams, Uh = rtpm_decompose(Tn, R, jax.random.PRNGKey(1),
                                  method=method, hash_len=J, n_sketches=Dn,
                                  n_inits=L, n_iters=T)
        Rm = cp_reconstruct(lams, Uh)
        return (float(jnp.linalg.norm(Tn - Rm) / nT),
                float(jnp.linalg.norm(Tc - Rm) / nC))

    # Fig. 1 sweep
    for method in methods:
        for J in (Js if method != "plain" else Js[:1]):
            sec = timeit(lambda m=method, j=J: once(m, j, D), reps=1,
                         warmup=0)
            r_obs, r_clean = once(method, J, D)
            emit(f"rtpm_fig1/{method}/J{J}/D{D}", sec,
                 f"res_obs={r_obs:.4f};res_clean={r_clean:.4f}")
            if method == "plain":
                break

    if table2:
        # Table 2: HCS vs FCS at matched sketched dims (I=50 scale)
        for J2, D2 in ((300, 10), (300, 20)):
            J1 = max(4, round((3 * J2 - 2) ** (1 / 3)))
            for method, J in (("hcs", J1), ("fcs", J2)):
                sec = timeit(lambda m=method, j=J, d=D2: once(m, j, d),
                             reps=1, warmup=0)
                r_obs, r_clean = once(method, J, D2)
                emit(f"rtpm_table2/{method}/J{J}/D{D2}", sec,
                     f"res_obs={r_obs:.4f};res_clean={r_clean:.4f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper-size", action="store_true",
                    help="I=100, J up to 10000 (slow on CPU)")
    args = ap.parse_args()
    if args.paper_size:
        run(I=100, Js=(1000, 4000, 10000), D=2)  # Fig. 1's exact D
    else:
        run()


if __name__ == "__main__":
    main()
