"""Roofline report generator: reads results/dryrun*.json and prints the
per-(arch x shape x mesh) three-term table + bottleneck + 6ND ratios.

  PYTHONPATH=src python -m benchmarks.roofline [--json results/dryrun.json]
      [--md results/roofline.md] [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import json


def fmt_table(results, mesh=None):
    rows = []
    hdr = ("arch", "shape", "mesh", "strat", "compute_ms", "memory_ms",
           "coll_ms", "dominant", "peak_GiB", "useful_ratio", "step_LB_ms")
    rows.append(hdr)
    for r in sorted(results, key=lambda r: (r["arch"], r["shape"],
                                            r["mesh"])):
        if r.get("tag"):
            continue
        if mesh and r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            rows.append((r["arch"], r["shape"], r["mesh"], "-", "-", "-",
                         "-", "SKIP(full-attn @500k)", "-", "-", "-"))
            continue
        if r["status"] != "ok":
            rows.append((r["arch"], r["shape"], r["mesh"], "-", "-", "-",
                         "-", "ERROR", "-", "-", "-"))
            continue
        rf = r["roofline"]
        rows.append((
            r["arch"], r["shape"], r["mesh"], r.get("strategy", "?"),
            f"{rf['t_compute_s']*1e3:.1f}", f"{rf['t_memory_s']*1e3:.1f}",
            f"{rf['t_collective_s']*1e3:.1f}", rf["dominant"],
            f"{r['memory']['peak_bytes_per_device']/2**30:.2f}",
            f"{r['useful_flops_ratio']:.3f}",
            f"{rf['step_lower_bound_s']*1e3:.1f}",
        ))
    widths = [max(len(str(row[i])) for row in rows)
              for i in range(len(hdr))]
    lines = []
    for i, row in enumerate(rows):
        lines.append(" | ".join(str(c).ljust(w) for c, w in
                                zip(row, widths)))
        if i == 0:
            lines.append("-+-".join("-" * w for w in widths))
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="results/dryrun.json")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--md", default=None)
    args = ap.parse_args()
    with open(args.json) as f:
        results = json.load(f)
    table = fmt_table(results, args.mesh)
    print(table)
    if args.md:
        with open(args.md, "w") as f:
            f.write("```\n" + table + "\n```\n")


if __name__ == "__main__":
    main()
