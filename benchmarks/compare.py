"""Compare two bench JSON artifacts (``benchmarks.run --json``) and print
the trend — the CI bench-smoke job runs this against the previous
commit's artifact so the perf trajectory (tok/s, hit rates, paged-KV
bytes) is published per commit, not just archived.

  python -m benchmarks.compare baseline.json current.json

Informational by default (exit 0): machine noise on shared CI runners
makes hard latency gates flaky; the table is for humans and the artifact
trail.  ``--max-regress R`` turns it into a gate: exit 1 if any row's
us_per_call regressed by more than the factor R.
"""
from __future__ import annotations

import argparse
import json
import sys

# derived metrics worth tracking across commits (higher-is-better marked)
TRACKED = ("tok_s", "hit_rate", "kv_peak_reserved_bytes",
           "kv_peak_used_bytes", "kv_reduction", "cached_bytes",
           "sketch_bytes_ratio")


def _load(path: str) -> dict:
    with open(path) as f:
        rows = json.load(f)
    return {r["name"]: r for r in rows}


def _metrics(row: dict) -> dict:
    m = row.get("metrics")
    if m is None:                     # artifact from before metrics existed
        from benchmarks.run import _parse_derived
        m = _parse_derived(row.get("derived", ""))
    return m


def compare(base: dict, cur: dict, max_regress: float = 0.0) -> int:
    names = list(cur) + [n for n in base if n not in cur]
    worst = 0.0
    print(f"{'name':44s} {'us/call':>12s} {'Δ':>8s}  tracked metrics")
    for n in names:
        b, c = base.get(n), cur.get(n)
        if c is None:
            print(f"{n:44s} {'(gone)':>12s}")
            continue
        us = c["us_per_call"]
        if b is None:
            print(f"{n:44s} {us:12.2f} {'(new)':>8s}")
            continue
        ratio = us / max(b["us_per_call"], 1e-12)
        worst = max(worst, ratio)
        bits = []
        bm, cm = _metrics(b), _metrics(c)
        for k in TRACKED:
            if k in cm and isinstance(cm[k], float):
                if isinstance(bm.get(k), float) and bm[k] not in (0.0,):
                    bits.append(f"{k}={cm[k]:g} ({cm[k]/bm[k]-1.0:+.0%})")
                else:
                    bits.append(f"{k}={cm[k]:g}")
        print(f"{n:44s} {us:12.2f} {ratio:7.2f}x  {'; '.join(bits)}")
    if max_regress and worst > max_regress:
        print(f"# FAIL: worst us/call regression {worst:.2f}x exceeds "
              f"--max-regress {max_regress}", file=sys.stderr)
        return 1
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="previous bench JSON artifact")
    ap.add_argument("current", help="freshly produced bench JSON")
    ap.add_argument("--max-regress", type=float, default=0.0,
                    help="fail (exit 1) if any row's us_per_call grew by "
                         "more than this factor (0 = informational)")
    args = ap.parse_args()
    sys.exit(compare(_load(args.baseline), _load(args.current),
                     args.max_regress))


if __name__ == "__main__":
    main()
