"""Compare bench JSON artifacts (``benchmarks.run --json``) and print the
trend — the CI bench-smoke job runs this against the previous commit's
artifact so the perf trajectory (tok/s, hit rates, paged-KV bytes,
speculative speedup) is published per commit, not just archived.

  python -m benchmarks.compare baseline.json current.json

Informational by default (exit 0): machine noise on shared CI runners
makes hard latency gates flaky; the table is for humans and the artifact
trail.  ``--max-regress R`` turns it into a gate: exit 1 if any row's
us_per_call regressed by more than the factor R.  ``--warn-only``
downgrades that gate to a GitHub Actions ``::warning::`` annotation
(exit 0) for jobs that only want the run-summary note.

  python -m benchmarks.compare base.json cur.json --max-regress 2.0 \
      --spread-files r1.json r2.json r3.json

``--spread-files`` hardens the gate against runner noise with the SAME
commit's repeat artifacts (the smoke job runs the bench 3x): each row's
threshold is raised from the global ``--max-regress`` floor to
``1 + SPREAD_MARGIN *`` its measured relative spread when the row is
noisier than the floor allows — a quiet row is gated tight, a noisy row
is never gated below what its own jitter can produce.  Rows absent from
the repeats keep the global floor.

``--missing-baseline-ok`` treats an unreadable or corrupt BASELINE
artifact as "no trend yet" (::warning:: + exit 0) instead of an error —
a poisoned artifact from a previous run must not block publishing the
current one.  The current artifact is never excused.

  python -m benchmarks.compare --spread r1.json r2.json [r3.json ...]

``--spread`` characterizes run-to-run noise instead: given repeats of
the SAME commit's bench it prints each row's min/max/relative spread and
a summary of the worst spread — the number that tells you what
``--max-regress`` threshold the runners can actually support.
"""
from __future__ import annotations

import argparse
import json
import sys

# derived metrics worth tracking across commits (higher-is-better marked)
TRACKED = ("tok_s", "hit_rate", "kv_peak_reserved_bytes",
           "kv_peak_used_bytes", "kv_reduction", "cached_bytes",
           "sketch_bytes_ratio", "spec_speedup", "accept_rate",
           "mean_accepted_run", "kv_tail_bytes", "tail_cosine",
           "paged_kernel_speedup", "kernel_tok_s", "verify_us_kernel",
           "ttft_p50_ms", "ttft_p99_ms", "itl_p50_ms", "itl_p99_ms",
           "obs_overhead", "tok_s_on")

# how many multiples of a row's measured run-to-run spread the per-row
# gate allows before calling a regression (see --spread-files)
SPREAD_MARGIN = 3.0


def _load(path: str) -> dict:
    with open(path) as f:
        rows = json.load(f)
    return {r["name"]: r for r in rows}


def _metrics(row: dict) -> dict:
    m = row.get("metrics")
    if m is None:                     # artifact from before metrics existed
        from benchmarks.run import _parse_derived
        m = _parse_derived(row.get("derived", ""))
    return m


def row_spreads(paths: list) -> dict:
    """Per-row relative us_per_call spread across repeat artifacts:
    (max - min) / min for every row present in ALL repeats.  Unreadable
    repeats are dropped with a ::warning:: (same philosophy as
    --missing-baseline-ok: a poisoned historical artifact must not
    block the current run); fewer than two usable repeats means no
    spread estimate — rows keep the global --max-regress floor."""
    runs = []
    for p in paths:
        try:
            runs.append(_load(p))
        except (OSError, ValueError, KeyError, TypeError) as e:
            print(f"::warning title=bench spread file unusable::{p}: {e}")
    if len(runs) < 2:
        return {}
    out = {}
    for n in runs[0]:
        if all(n in r for r in runs):
            vals = [r[n]["us_per_call"] for r in runs]
            out[n] = (max(vals) - min(vals)) / max(min(vals), 1e-12)
    return out


def compare(base: dict, cur: dict, max_regress: float = 0.0,
            warn_only: bool = False, spreads: dict = None) -> int:
    """Print the trend table; gate on per-row regressions.

    With ``spreads`` (row -> relative run-to-run spread, from the same
    commit's repeats) each row's threshold is
    ``max(max_regress, 1 + SPREAD_MARGIN * spread)`` — the global floor,
    lifted only for rows whose own measured noise exceeds it."""
    names = list(cur) + [n for n in base if n not in cur]
    failures = []

    def _ident(rows: dict) -> str:
        # ts/sha stamped by benchmarks.run --json (same on every row);
        # older artifacts predate the stamp — show a placeholder
        r = next(iter(rows.values()), {})
        return f"{r.get('sha', '?')} @ {r.get('ts', '?')}"

    print(f"# baseline {_ident(base)}  ->  current {_ident(cur)}")
    print(f"{'name':44s} {'us/call':>12s} {'Δ':>8s}  tracked metrics")
    for n in names:
        b, c = base.get(n), cur.get(n)
        if c is None:
            print(f"{n:44s} {'(gone)':>12s}")
            continue
        us = c["us_per_call"]
        if b is None:
            print(f"{n:44s} {us:12.2f} {'(new)':>8s}")
            continue
        ratio = us / max(b["us_per_call"], 1e-12)
        if max_regress:
            limit = max(max_regress,
                        1.0 + SPREAD_MARGIN * (spreads or {}).get(n, 0.0))
            if ratio > limit:
                failures.append((n, ratio, limit))
        bits = []
        bm, cm = _metrics(b), _metrics(c)
        for k in TRACKED:
            if k in cm and isinstance(cm[k], float):
                if isinstance(bm.get(k), float) and bm[k] not in (0.0,):
                    bits.append(f"{k}={cm[k]:g} ({cm[k]/bm[k]-1.0:+.0%})")
                else:
                    bits.append(f"{k}={cm[k]:g}")
        print(f"{n:44s} {us:12.2f} {ratio:7.2f}x  {'; '.join(bits)}")
    if failures:
        failures.sort(key=lambda f: f[1] / f[2], reverse=True)
        msg = "; ".join(f"{n} {r:.2f}x (limit {lim:.2f}x)"
                        for n, r, lim in failures)
        if warn_only:
            # GitHub Actions annotation: lands on the run summary page
            print(f"::warning title=bench regression::{msg}")
            return 0
        print(f"# FAIL: us/call regressions past their per-row limits: "
              f"{msg}", file=sys.stderr)
        return 1
    return 0


def spread(paths: list) -> int:
    """Noise characterization: rows across N repeats of the same bench.
    Relative spread = (max - min) / min of us_per_call per row."""
    runs = [_load(p) for p in paths]
    names = [n for n in runs[0] if all(n in r for r in runs)]
    worst = 0.0
    worst_name = ""
    print(f"# spread over {len(runs)} repeats")
    print(f"{'name':44s} {'min us':>10s} {'max us':>10s} {'spread':>8s}")
    for n in names:
        vals = [r[n]["us_per_call"] for r in runs]
        lo, hi = min(vals), max(vals)
        rel = (hi - lo) / max(lo, 1e-12)
        if rel > worst:
            worst, worst_name = rel, n
        print(f"{n:44s} {lo:10.2f} {hi:10.2f} {rel:7.1%}")
    print(f"# worst run-to-run spread: {worst:.1%} ({worst_name}) — a "
          f"--max-regress gate below {1 + worst:.2f}x would flake on "
          f"noise alone")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("artifacts", nargs="+",
                    help="baseline + current JSON (or N repeats with "
                         "--spread)")
    ap.add_argument("--max-regress", type=float, default=0.0,
                    help="fail (exit 1) if any row's us_per_call grew by "
                         "more than this factor (0 = informational)")
    ap.add_argument("--warn-only", action="store_true",
                    help="emit a ::warning:: annotation instead of "
                         "failing when --max-regress trips")
    ap.add_argument("--spread", action="store_true",
                    help="treat the artifacts as repeats of one bench "
                         "and report per-row run-to-run spread")
    ap.add_argument("--spread-files", nargs="+", default=[],
                    metavar="JSON",
                    help="repeat artifacts of the CURRENT commit; raises "
                         "each row's gate to 1 + SPREAD_MARGIN * its "
                         "measured relative spread when noisier than "
                         "--max-regress")
    ap.add_argument("--missing-baseline-ok", action="store_true",
                    help="warn + exit 0 when the baseline artifact is "
                         "missing or corrupt (the current artifact is "
                         "never excused)")
    args = ap.parse_args()
    if args.spread:
        if len(args.artifacts) < 2:
            ap.error("--spread needs at least two repeat artifacts")
        sys.exit(spread(args.artifacts))
    if len(args.artifacts) != 2:
        ap.error("expected exactly: baseline.json current.json")
    try:
        base = _load(args.artifacts[0])
    except (OSError, ValueError, KeyError, TypeError) as e:
        if not args.missing_baseline_ok:
            raise
        print(f"::warning title=bench baseline unusable::"
              f"{args.artifacts[0]}: {e} — skipping trend")
        sys.exit(0)
    spreads = row_spreads(args.spread_files) if args.spread_files else None
    sys.exit(compare(base, _load(args.artifacts[1]),
                     args.max_regress, args.warn_only, spreads))


if __name__ == "__main__":
    main()
