"""Shared benchmark utilities: timing + CSV emission."""
from __future__ import annotations

import time
from typing import Callable

import jax

ROWS = []


def timeit(fn: Callable, *args, reps: int = 3, warmup: int = 1) -> float:
    """Median wall seconds of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.time()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.time() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def time_once(fn, *args):
    """(seconds, result) for a single blocking call."""
    t0 = time.time()
    out = fn(*args)
    jax.block_until_ready(out)
    return time.time() - t0, out


def emit(name: str, seconds: float, derived: str = "") -> None:
    """CSV row: name,us_per_call,derived."""
    row = f"{name},{seconds * 1e6:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)
