"""Serving examples: the batched generate facade AND the request-stream
continuous-batching API underneath it.

  PYTHONPATH=src python examples/serve_lm.py [--arch gemma-2b]

Part 1 uses ``ServeEngine.generate`` — the classic (B, S) prompts in,
(B, max_new) tokens out API.  Part 2 drives ``SlotScheduler`` directly:
submit requests of mixed prompt lengths, pump ``step()``, and collect
completions as they retire — the decode step compiles exactly once and
hot prompt prefixes get admitted to the count-min gated KV cache.
Part 3 (attention families) turns on SPECULATIVE decoding: a draft model
derived from the served weights (``models/draft.py`` — here a truncated
single-layer stack) proposes ``spec_k`` tokens per round and the target
verifies them all in one multi-query step; greedy output is bitwise the
plain-decode output, and the acceptance rate tells you how much of the
draft's work survived verification.
Part 4 (attention families) turns on SKETCHED LONG-CONTEXT KV
(``serve/kv_sketch.py``): each slot keeps only the most recent
``kv_sketch_window`` rows as exact paged blocks; older blocks fold into
per-slot FCS tail tables and return to the pool, so a slot decodes a
context several times larger than its reserved blocks could hold.
Part 5 goes ASYNC (``serve/frontend.py``): ``AsyncServeEngine.submit``
returns a StreamHandle, tokens arrive per decode chunk through
``async for tok in handle.stream()``, and an impatient client's
``handle.cancel()`` retires the slot and frees its blocks mid-flight —
the survivors decode on, bitwise unperturbed.
Part 6 adds OBSERVABILITY (``repro.obs``): the same streamed +
cancelled pair runs with a ``ServeObserver`` attached — the request
lifecycle (queued -> admitted -> token deliveries -> done/cancelled)
and the pump's dispatch/collect phases land in a Chrome trace JSON you
can open in Perfetto, while windowed metrics (TTFT, queue wait,
per-status completions) accumulate in the registry.  All host-side:
the served tokens are bitwise the Part 5 tokens.
"""
import argparse
import asyncio
import dataclasses
import time

import jax
import numpy as np

from repro.configs.registry import ARCH_IDS, reduced_config
from repro.models import model as M
from repro.obs import ServeObserver, Tracer, write_trace
from repro.serve.engine import ServeEngine
from repro.serve.frontend import AsyncServeEngine
from repro.serve.scheduler import KV_FAMILIES, Request, SlotScheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    k_params, k_prompts = jax.random.split(jax.random.PRNGKey(0))
    params = M.init_params(k_params, cfg)

    # -- Part 1: batched generate facade --------------------------------
    engine = ServeEngine(cfg, params,
                         max_seq=args.prompt_len + args.max_new + 8)
    prompts = jax.random.randint(k_prompts, (args.batch, args.prompt_len),
                                 0, cfg.vocab_size)
    t0 = time.time()
    res = engine.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    n = args.batch * args.max_new
    print(f"[generate] {n} tokens in {dt:.2f}s "
          f"({n/dt:.1f} tok/s incl. compile)")
    for i in range(min(2, args.batch)):
        print(f"  seq {i}:", res.tokens[i, :8].tolist())

    # -- Part 2: request-stream API --------------------------------------
    # every family rides the slot scheduler now: attention families get
    # chunked prefill + the prefix cache, ssm/hybrid get slot-inserted
    # recurrent state; sampling params are per-request.
    serve = dataclasses.replace(cfg.serve, max_batch=2, max_seq=128,
                                admit_threshold=2)
    sched = SlotScheduler(cfg, params, serve=serve)
    rng = np.random.RandomState(0)
    system = rng.randint(0, cfg.vocab_size, (32,)).astype(np.int32)
    for rid in range(6):
        # mixed lengths, all sharing the 32-token "system prompt"; odd
        # rids ask for seeded top-k sampling, even rids decode greedily —
        # both share the one compiled decode chunk.
        tail = rng.randint(0, cfg.vocab_size,
                           size=rng.randint(1, 9)).astype(np.int32)
        sched.submit(Request(rid=rid, tokens=np.concatenate([system, tail]),
                             max_new=6,
                             temperature=0.8 if rid % 2 else 0.0,
                             top_k=8 if rid % 2 else 0,
                             seed=rid if rid % 2 else None))
    while sched.pending:
        done = sched.step()          # admit -> one decode chunk -> retire
        for c in done:
            print(f"[stream] rid {c.rid} (prompt {c.prompt_len}, "
                  f"prefix_hit={c.prefix_hit}): {c.tokens.tolist()}")
    print(f"[stream] decode compilations: {sched.decode_compilations}, "
          f"prefill compilations: {sched.prefill_compilations}")
    if cfg.family in KV_FAMILIES:
        st = sched.prefix_cache.stats
        print(f"[stream] hit rate {st.hit_rate:.2f}, "
              f"cached bytes {st.bytes}")

    # -- Part 3: speculative decoding -------------------------------------
    # a spec_k > 0 serve config derives a draft (truncated stack by
    # default; set draft_sketch_ratio for the count-sketch-compressed
    # variant) and the engine proposes/verifies per round.  Greedy output
    # is token-for-token what plain decode produces — speculation is a
    # latency optimization, never a correctness trade.
    if cfg.family in KV_FAMILIES:
        spec_serve = dataclasses.replace(serve, spec_k=3, draft_depth=1)
        spec = SlotScheduler(cfg, params, serve=spec_serve)
        prompt = np.concatenate(
            [system, rng.randint(0, cfg.vocab_size, (5,)).astype(np.int32)])
        done = spec.run([Request(rid=100, tokens=prompt, max_new=12)])
        plain = sched.run([Request(rid=101, tokens=prompt, max_new=12)])
        assert done[0].tokens.tolist() == plain[0].tokens.tolist()
        print(f"[spec] tokens: {done[0].tokens.tolist()} "
              f"(identical to plain greedy)")
        print(f"[spec] acceptance rate {spec.acceptance_rate:.2f}, "
              f"mean accepted run {spec.mean_accepted_run:.2f} "
              f"tokens/round over {spec.spec_rounds} rounds")

    # -- Part 4: sketched long-context KV ---------------------------------
    # a small pool (10 blocks x 16 rows = 160 exact rows) serves a
    # 400-token prompt: blocks aging past the 64-row window fold into the
    # slot's FCS tail tables inside the compiled chunk and return to the
    # pool, so reserved blocks track the WINDOW, not the context.
    if cfg.family in KV_FAMILIES:
        bs = cfg.serve.kv_block_size
        lc_serve = dataclasses.replace(
            cfg.serve, max_batch=1, max_seq=512, num_kv_blocks=10,
            kv_sketch_window=4 * bs, admit_threshold=1 << 30)
        lc = SlotScheduler(cfg, params, serve=lc_serve)
        doc = rng.randint(0, cfg.vocab_size, (400,)).astype(np.int32)
        done = lc.run([Request(rid=200, tokens=doc, max_new=8)])
        pool_rows = lc.num_blocks * lc.block_size
        print(f"[sketch] {len(doc)}-token context through a "
              f"{pool_rows}-row pool: {done[0].tokens.tolist()}")
        print(f"[sketch] tail tables {lc.kv_sketch_tail_bytes()}B fixed "
              f"vs dense {lc.kv_dense_equiv_bytes()}B; "
              f"decode compilations: {lc.decode_compilations}")

    # -- Part 5: streaming + cancellation ---------------------------------
    # the async front-end: submit() -> StreamHandle, tokens stream back
    # per decode chunk, and hanging up mid-stream (cancel()) frees the
    # slot and its pool blocks at the next pump boundary.  One request
    # streams to the end; a second cancels itself after 4 tokens.
    front = AsyncServeEngine(cfg, params, serve=serve)
    p1 = rng.randint(0, cfg.vocab_size, (12,)).astype(np.int32)
    p2 = rng.randint(0, cfg.vocab_size, (9,)).astype(np.int32)

    async def stream_two():
        patient = await front.submit(p1, max_new=10)
        impatient = await front.submit(p2, max_new=24)

        async def consume(handle, hang_up_after=None):
            got = []
            async for tok in handle.stream():
                got.append(tok)
                if hang_up_after and len(got) >= hang_up_after:
                    handle.cancel()          # client went away
            return got

        full, partial = await asyncio.gather(consume(patient),
                                             consume(impatient, 4))
        return patient, impatient, full, partial

    patient, impatient, full, partial = asyncio.run(stream_two())
    print(f"[async] rid {patient.rid} streamed {full} "
          f"(status {patient.completion.status})")
    print(f"[async] rid {impatient.rid} hung up after {partial} "
          f"(status {impatient.completion.status}, "
          f"budget was 24)")
    st = front.stats()
    print(f"[async] engine stats: completed={st.completed} "
          f"cancelled={st.cancelled}, pool free "
          f"{st.blocks_free}/{st.pool_blocks} blocks")

    # -- Part 6: tracing a streamed + cancelled request --------------------
    # attach a ServeObserver (tracer + metrics registry) and replay the
    # Part 5 shape: one patient stream, one mid-stream hangup.  Every
    # hook is host-side bookkeeping — tokens match Part 5 bitwise.
    obs = ServeObserver(tracer=Tracer(sample_rate=1.0),
                        metrics_interval=0.0)
    sched6 = SlotScheduler(cfg, params, serve=serve, obs=obs)
    front6 = AsyncServeEngine(scheduler=sched6)

    async def stream_traced():
        patient = await front6.submit(p1, max_new=10)
        impatient = await front6.submit(p2, max_new=24)

        async def consume(handle, hang_up_after=None):
            got = []
            async for tok in handle.stream():
                got.append(tok)
                if hang_up_after and len(got) >= hang_up_after:
                    handle.cancel()
            return got

        return await asyncio.gather(consume(patient),
                                    consume(impatient, 4))

    full6, partial6 = asyncio.run(stream_traced())
    assert full6 == full and partial6 == partial, "observer changed tokens"
    write_trace(obs.tracer, "serve_trace.json")
    w = obs.flush(stats=sched6.stats())
    reg = obs.registry
    print(f"[obs] trace: {len(obs.tracer)} events -> serve_trace.json "
          f"(open in https://ui.perfetto.dev)")
    print(f"[obs] totals: ok={w['counters']['serve.completions.ok']['total']:.0f} "
          f"cancelled={w['counters']['serve.completions.cancelled']['total']:.0f} "
          f"over {len(obs.windows)} windows; "
          f"ttft_p50={reg.hist('serve.ttft_s').quantile(0.5)*1e3:.0f}ms "
          f"queue_wait_p90={reg.hist('serve.queue_wait_s').quantile(0.9)*1e3:.1f}ms")


if __name__ == "__main__":
    main()
