"""Serving example: batched greedy generation through the prefill+decode
engine (the same serve_step the multi-pod dry-run lowers).

  PYTHONPATH=src python examples/serve_lm.py [--arch gemma-2b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, reduced_config
from repro.models import model as M
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    engine = ServeEngine(cfg, params,
                         max_seq=args.prompt_len + args.max_new + 8)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    res = engine.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    n = args.batch * args.max_new
    print(f"{n} tokens in {dt:.2f}s ({n/dt:.1f} tok/s incl. compile)")
    for i in range(min(2, args.batch)):
        print(f"seq {i}:", res.tokens[i].tolist())


if __name__ == "__main__":
    main()
