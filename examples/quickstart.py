"""Quickstart: the FCS sketching API in 60 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (fcs_cp, fcs_general, fcs_sketch_len, fcs_tiuu,
                        fcs_tuuu, make_tensor_hashes, median_combine,
                        ts_general)

key = jax.random.PRNGKey(0)

# A symmetric CP rank-4 tensor (40 x 40 x 40), orthonormal factors
R, I = 4, 40
ks = jax.random.split(key, 4)
U = jnp.linalg.qr(jax.random.normal(ks[0], (I, I)))[0][:, :R]
Us = [U, U, U]
lam = jnp.arange(R, 0, -1).astype(jnp.float32)
T = jnp.einsum("ar,br,cr,r->abc", *Us, lam)

# D=8 independent sketches, per-mode hash length 1024
hashes = make_tensor_hashes(ks[3], T.shape, 1024, D=8)
print(f"sketch length J~ = {fcs_sketch_len([mh.J for mh in hashes])} "
      f"(vs {T.size} entries)")

# FCS two ways: O(nnz) general path == FFT CP fast path (Eq. 8)
sk_general = fcs_general(T, hashes)
sk_cp = fcs_cp(lam, Us, hashes)
print("CP fast path max dev:",
      float(jnp.max(jnp.abs(sk_general - sk_cp))))

# sketched tensor contractions (the paper's core application, Eqs. 16/17)
# u aligned with the leading component, as in a power-method iteration
u = Us[0][:, 0] / jnp.linalg.norm(Us[0][:, 0])
exact_tuuu = float(jnp.einsum("abc,a,b,c->", T, u, u, u))
est_tuuu = float(median_combine(fcs_tuuu(sk_general, u, hashes)))
print(f"T(u,u,u): exact {exact_tuuu:+.4f}  sketched {est_tuuu:+.4f}")

exact_tiuu = jnp.einsum("abc,b,c->a", T, u, u)
est_tiuu = median_combine(fcs_tiuu(sk_general, u, hashes))
rel = float(jnp.linalg.norm(est_tiuu - exact_tiuu)
            / jnp.linalg.norm(exact_tiuu))
print(f"T(I,u,u): rel err {rel:.3f}")

# FCS vs TS at the same hashes (Prop. 1: FCS variance <= TS variance)
M = jax.random.normal(ks[0], T.shape)
N = jax.random.normal(ks[1], T.shape)
exact = float(jnp.vdot(M, N))
big = make_tensor_hashes(key, T.shape, 64, D=128)
e_fcs = jnp.sum(fcs_general(M, big) * fcs_general(N, big), -1)
e_ts = jnp.sum(ts_general(M, big) * ts_general(N, big), -1)
print(f"<M,N> exact {exact:+.1f} | FCS var {float(jnp.var(e_fcs)):.1f} "
      f"| TS var {float(jnp.var(e_ts)):.1f}  (FCS <= TS)")
