"""FCS-accelerated CP decomposition (the paper's flagship application):
decompose a noisy low-rank tensor with plain vs TS vs FCS RTPM.

  PYTHONPATH=src python examples/cpd_sketched.py
"""
import time

import jax
import jax.numpy as jnp

from repro.cpd.rtpm import cp_reconstruct, rtpm_decompose

key = jax.random.PRNGKey(0)
I, R = 50, 8
Q, _ = jnp.linalg.qr(jax.random.normal(key, (I, I)))
U = Q[:, :R]
T_clean = jnp.einsum("ar,br,cr->abc", U, U, U)
T = T_clean + 0.01 * jax.random.normal(key, (I, I, I))
nc = float(jnp.linalg.norm(T_clean))

print(f"symmetric CP rank-{R} tensor, {I}^3, sigma=0.01")
for method, J, D in (("plain", 0, 0), ("ts", 800, 10), ("fcs", 800, 10)):
    t0 = time.time()
    lams, Uh = rtpm_decompose(T, R, jax.random.PRNGKey(1), method=method,
                              hash_len=J, n_sketches=max(D, 1),
                              n_inits=12, n_iters=15)
    rr = float(jnp.linalg.norm(T_clean - cp_reconstruct(lams, Uh)) / nc)
    print(f"  {method:6s} J={J:4d} D={D:2d}: clean-residual {rr:.4f} "
          f"({time.time()-t0:.1f}s)")
print("expected ordering: plain < fcs <= ts (Prop. 1)")
