"""End-to-end driver: train a reduced LM for a few hundred steps on CPU,
with checkpointing and (optionally) FCS gradient compression.

  PYTHONPATH=src python examples/train_lm.py [--arch yi-9b] [--steps 300]
      [--grad-compression]
"""
import argparse

from repro.configs.registry import ARCH_IDS, reduced_config
from repro.train.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="yi-9b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=5e-3)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/fcs_train_example")
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    hist = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                 lr=args.lr, ckpt_dir=args.ckpt_dir, ckpt_every=100,
                 grad_compression=args.grad_compression or None,
                 log_every=25)
    print(f"\nfinal loss {hist.losses[-1]:.4f} "
          f"(from {hist.losses[0]:.4f} over {len(hist.losses)} steps); "
          f"median step {sorted(hist.step_times)[len(hist.step_times)//2]*1e3:.0f} ms")


if __name__ == "__main__":
    main()
