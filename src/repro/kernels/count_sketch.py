"""Pallas TPU kernel: count-sketch apply as a blocked signed-one-hot MXU
matmul.

TPUs have no efficient random scatter; the TPU-native formulation of
CS(x)_j = sum_{h(i)=j} s(i) x(i) is y = x @ O with O[i, j] = s(i)*[h(i)=j].
The kernel builds each (bI, bJ) one-hot tile IN VMEM from the hash tables
(broadcasted-iota compare + sign multiply) and immediately contracts it on
the MXU with the (bB, bI) input tile, accumulating f32 partials in the
(bB, bJ) output tile.  The one-hot matrix never exists in HBM, so HBM
traffic is O(B*I + B*J + I) per sketch instead of O(I*J).

Grid: (J/bJ, B/bB, I/bI) — I is the innermost (reduction) axis so the
output tile revisits stay in VMEM (TPU grids iterate minor-most fastest).
Block sizes default to MXU-aligned (128, 128) multiples.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cs_kernel(x_ref, h_ref, s_ref, o_ref, *, bJ: int):
    j0 = pl.program_id(0) * bJ

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    h = h_ref[...]                                   # (bI,) int32
    s = s_ref[...]                                   # (bI,) f32
    cols = j0 + jax.lax.broadcasted_iota(jnp.int32, (h.shape[0], bJ), 1)
    onehot = jnp.where(cols == h[:, None], s[:, None], 0.0)
    x = x_ref[...]                                   # (bB, bI)
    o_ref[...] += jax.lax.dot(x.astype(jnp.float32), onehot,
                              preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("J", "bB", "bI", "bJ",
                                             "interpret"))
def count_sketch(x: jax.Array, h: jax.Array, s: jax.Array, J: int,
                 bB: int = 128, bI: int = 512, bJ: int = 256,
                 interpret: bool | None = None) -> jax.Array:
    """x: (B, I) -> (B, J) count sketch with shared hash (h, s).

    interpret=None auto-detects the backend: compiled on TPU, interpret
    mode (kernel body in Python — bit-identical block semantics) off-TPU.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, I = x.shape
    bB = min(bB, B)
    bI = min(bI, I)
    bJ = min(bJ, J)
    padB, padI, padJ = (-B) % bB, (-I) % bI, (-J) % bJ
    if padB or padI:
        x = jnp.pad(x, ((0, padB), (0, padI)))
    if padI:
        h = jnp.pad(h, (0, padI), constant_values=J + padJ + 1)  # out of range
        s = jnp.pad(s, (0, padI))
    Jp = J + padJ
    grid = (Jp // bJ, x.shape[0] // bB, x.shape[1] // bI)
    out = pl.pallas_call(
        functools.partial(_cs_kernel, bJ=bJ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bB, bI), lambda j, b, i: (b, i)),
            pl.BlockSpec((bI,), lambda j, b, i: (i,)),
            pl.BlockSpec((bI,), lambda j, b, i: (i,)),
        ],
        out_specs=pl.BlockSpec((bB, bJ), lambda j, b, i: (b, j)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], Jp), jnp.float32),
        interpret=interpret,
    )(x, h, s.astype(jnp.float32))
    return out[:B, :J].astype(x.dtype)
