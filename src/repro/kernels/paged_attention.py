"""Flash-decode paged attention: one Pallas pass over a slot's block table.

The serve path (models/layers.py paged modes) currently gathers a slot's
pool blocks into a dense (B, S, K, hd) buffer with ``jnp.take`` and then
runs a full masked softmax.  This kernel attends IN ONE PASS instead: the
grid walks (slot, logical block), the block table rides as a
scalar-prefetch operand so each step's BlockSpec index map fetches the
slot's next physical KV block directly from the pool, and f32 online-
softmax statistics (running max / weight sum / weighted value
accumulator) merge the blocks — the dense gathered copy never exists.

One kernel covers the three serve shapes (they differ only in the query
geometry):

  decode   q: (B, 1, K, R, hd),  start = per-slot position (B,)
  verify   q: (B, C, K, R, hd),  start = per-slot first position (B,)
  chunk    q: (1, C, K, R, hd),  start = traced chunk offset (1,)

Masking matches the jnp paths row for row: query i of slot b sees key
position p iff ``fold_base[b] <= p <= start[b] + i`` and p lies in a
block whose table entry is a real pool id (entries >= num_blocks mark
unallocated / invalidated rows — the whole block is masked dead and the
index map clamps the fetch, so retired slots read nothing).  With
``fold_base == 0`` the lower bound is vacuous and the statistics cover
the full causal span; with ``fold_base > 0`` they cover exactly the
two-span exact window, merge-ready against ``serve/kv_sketch.py``'s
``tail_attend`` output via ``merge_spans``.

Precision follows the repo's flash idiom (layers._flash_attention):
scores and running statistics are f32 (``preferred_element_type``); the
per-block weight tile is cast back to the value dtype for the weighted-
value MXU pass.  ``kernels/ref.py:paged_attention_ref`` mirrors the
block loop op for op, so interpret mode reproduces it bitwise.

Returns raw statistics, not normalized output: (m, l, acc) shaped
(B, K, R, Sq) / (B, K, R, Sq) / (B, K, R, Sq, hd), all f32.  Callers
normalize ``acc / max(l, eps)`` or merge with a sketched tail first.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _attend_block(tbl_ref, st_ref, fb_ref, q_ref, k_ref, v_ref,
                  m_ref, l_ref, acc_ref, *, bs, Sq, K, NQ, NB, scale):
    """Grid (B, nb_slot): fold pool block ``tbl[b, j]`` into slot b's
    running statistics.  NQ = R * Sq query rows per kv head; row r*Sq+i
    is query position i of q-head replica r."""
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid = tbl_ref[b, j] < NB
    kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (NQ, bs), 1)
    qi = jax.lax.broadcasted_iota(jnp.int32, (NQ, bs), 0) % Sq
    live = ((kpos <= st_ref[b] + qi) & (kpos >= fb_ref[b])) & valid
    for z in range(K):
        qz = q_ref[0, z]                              # (NQ, hd)
        kz = k_ref[0, :, z, :]                        # (bs, hd)
        vz = v_ref[0, :, z, :]
        s = jax.lax.dot_general(qz, kz, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = jnp.where(live, s, -1e30)
        m_prev = m_ref[0, z]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        # exp(-1e30 - (-1e30)) == 1 on fully-dead rows: re-zero after exp
        p = jnp.where(live, jnp.exp(s - m_cur[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_cur)
        m_ref[0, z] = m_cur
        l_ref[0, z] = l_ref[0, z] * corr + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(p.astype(vz.dtype), vz,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[0, z] = acc_ref[0, z] * corr[:, None] + pv


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                    tables: jax.Array, start: jax.Array,
                    fold_base: jax.Array, *,
                    interpret: bool | None = None):
    """Flash-decode attention through per-slot block tables.

    q: (B, Sq, K, R, hd); k_pool/v_pool: (NB, bs, K, hd) shared pool;
    tables: (B, nb_slot) int32 physical block ids (>= NB = dead row);
    start: (B,) int32 per-slot position of query row 0; fold_base: (B,)
    int32 lower visibility bound (zeros when no span is folded).

    interpret=None auto-detects: compiled on TPU, interpret elsewhere.
    Returns f32 (m, l, acc): (B, K, R, Sq) x2 and (B, K, R, Sq, hd).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Sq, K, R, hd = q.shape
    NB, bs = k_pool.shape[0], k_pool.shape[1]
    nb_slot = tables.shape[1]
    NQ = R * Sq
    scale = 1.0 / math.sqrt(hd)
    # (B, K, R*Sq, hd): kv-head-major rows so each head's queries are one
    # contiguous MXU tile inside the kernel
    qt = q.transpose(0, 2, 3, 1, 4).reshape(B, K, NQ, hd)

    def _kv_map(b, j, tbl, st, fb):
        # dead entries (>= NB) still need an in-range fetch; the kernel
        # masks the whole block so the clamped read is never used
        return (jnp.minimum(tbl[b, j], NB - 1), 0, 0, 0)

    kv_spec = pl.BlockSpec((1, bs, K, hd), _kv_map)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, nb_slot),
        in_specs=[
            pl.BlockSpec((1, K, NQ, hd), lambda b, j, *_: (b, 0, 0, 0)),
            kv_spec,
            kv_spec,
        ],
        out_specs=[
            pl.BlockSpec((1, K, NQ), lambda b, j, *_: (b, 0, 0)),
            pl.BlockSpec((1, K, NQ), lambda b, j, *_: (b, 0, 0)),
            pl.BlockSpec((1, K, NQ, hd), lambda b, j, *_: (b, 0, 0, 0)),
        ],
    )
    m, l, acc = pl.pallas_call(
        functools.partial(_attend_block, bs=bs, Sq=Sq, K=K, NQ=NQ,
                          NB=NB, scale=scale),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, K, NQ), jnp.float32),
            jax.ShapeDtypeStruct((B, K, NQ), jnp.float32),
            jax.ShapeDtypeStruct((B, K, NQ, hd), jnp.float32),
        ],
        interpret=interpret,
    )(tables.astype(jnp.int32), start.astype(jnp.int32),
      fold_base.astype(jnp.int32), qt, k_pool, v_pool)
    return (m.reshape(B, K, R, Sq), l.reshape(B, K, R, Sq),
            acc.reshape(B, K, R, Sq, hd))
