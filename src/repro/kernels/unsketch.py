"""Pallas TPU kernel: count-sketch decompress (gather) as a blocked one-hot
MXU matmul.

out[b, i] = s(i) * y[b, h(i)] — the transpose access pattern of the apply
kernel.  Each (bI, bJ) signed one-hot tile is built in VMEM and contracted
as y_tile @ onehot_tile^T, accumulating over J blocks (each row of onehot
has its single 1 in exactly one J block, so accumulation is exact)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _unsketch_kernel(y_ref, h_ref, s_ref, o_ref, *, bJ: int):
    j0 = pl.program_id(2) * bJ

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    h = h_ref[...]                                   # (bI,)
    s = s_ref[...]
    cols = j0 + jax.lax.broadcasted_iota(jnp.int32, (h.shape[0], bJ), 1)
    onehot = jnp.where(cols == h[:, None], s[:, None], 0.0)   # (bI, bJ)
    y = y_ref[...].astype(jnp.float32)               # (bB, bJ)
    o_ref[...] += jax.lax.dot_general(
        y, onehot, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (bB, bI)


@functools.partial(jax.jit, static_argnames=("bB", "bI", "bJ", "interpret"))
def unsketch(y: jax.Array, h: jax.Array, s: jax.Array,
             bB: int = 128, bI: int = 512, bJ: int = 256,
             interpret: bool | None = None) -> jax.Array:
    """y: (B, J), hash tables over I entries -> (B, I) estimates.

    interpret=None auto-detects: compiled on TPU, interpret mode off-TPU.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, J = y.shape
    I = h.shape[0]
    bB = min(bB, B)
    bI = min(bI, I)
    bJ = min(bJ, J)
    padB, padI, padJ = (-B) % bB, (-I) % bI, (-J) % bJ
    if padB or padJ:
        y = jnp.pad(y, ((0, padB), (0, padJ)))
    if padI:
        h = jnp.pad(h, (0, padI), constant_values=J + padJ + 1)
        s = jnp.pad(s, (0, padI))
    grid = (y.shape[0] // bB, (I + padI) // bI, y.shape[1] // bJ)
    out = pl.pallas_call(
        functools.partial(_unsketch_kernel, bJ=bJ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bB, bJ), lambda b, i, j: (b, j)),
            pl.BlockSpec((bI,), lambda b, i, j: (i,)),
            pl.BlockSpec((bI,), lambda b, i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((bB, bI), lambda b, i, j: (b, i)),
        out_shape=jax.ShapeDtypeStruct((y.shape[0], I + padI), jnp.float32),
        interpret=interpret,
    )(y, h, s.astype(jnp.float32))
    return out[:B, :I].astype(y.dtype)
