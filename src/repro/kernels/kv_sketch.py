"""Pallas TPU kernels: KV tail-table fold and query score estimation.

serve/kv_sketch.py expresses the long-context tail math as jnp einsums
against a precomputed (rows, T, cols) signed position one-hot — fine at
serve geometry, but the one-hot is T*cols floats per hash row.  These
kernels are the bandwidth-honest formulation, following
kernels/sketch_update.py: hashes are evaluated ON THE FLY per tile
(uint32 multiply-add + murmur finalize from sketch/hashing.py) and the
signed one-hot only ever exists as a (block, block) VMEM tile feeding an
MXU contraction.

  tail_fold   : rows (N, D) at absolute positions (N,) accumulate into a
                (Z, C, D) tail table — grid (C/bC, N/bN), reduction axis
                innermost so each table tile is revisited consecutively.
  tail_scores : per-query bucket products q @ tail_k[z]^T gathered back
                to per-position estimates, median-combined over hash rows
                in-kernel — grid (N/bN, T/bT), bucket products computed
                once per query block and parked in VMEM scratch.

Both run with ``interpret=None`` auto-detect (compiled on TPU, interpret
elsewhere) and are validated against kernels/ref.py oracles that
delegate to serve/kv_sketch.py — kernel and serve path share
sketch/hashing.py, so the hash arithmetic matches bitwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.sketch_update import _median_rows
from repro.sketch.hashing import bucket_hash, sign_hash


def _fold_kernel(p_ref, x_ref, t_ref, c_ref, o_ref, *,
                 bN: int, bC: int, C: int, Z: int):
    n_blk = pl.program_id(1)

    @pl.when(n_blk == 0)
    def _init():
        o_ref[...] = t_ref[...]

    idx = p_ref[...].astype(jnp.uint32)                       # (bN,)
    x = x_ref[...].astype(jnp.float32)                        # (bN, D)
    c0 = pl.program_id(0) * bC
    cols = c0 + jax.lax.broadcasted_iota(jnp.int32, (bN, bC), 1)
    for z in range(Z):
        bk = bucket_hash(idx, c_ref[z, 0], c_ref[z, 1], C)
        sg = sign_hash(idx, c_ref[z, 2], c_ref[z, 3])
        onehot = jnp.where(cols == bk[:, None], sg[:, None], 0.0)
        # (bC, bN) @ (bN, D): each bucket column sums its rows' signed hits
        o_ref[z, :, :] += jax.lax.dot_general(
            onehot, x, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)


def _scores_kernel(q_ref, t_ref, c_ref, o_ref, qa_ref, *,
                   bN: int, bT: int, C: int, Z: int):
    t_blk = pl.program_id(1)

    @pl.when(t_blk == 0)
    def _products():
        q = q_ref[...].astype(jnp.float32)                    # (bN, D)
        for z in range(Z):
            # bucket products: one (bN, C) row of q . tail_k[z, c] per z
            qa_ref[z, :, :] = jax.lax.dot_general(
                q, t_ref[z, :, :], (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)

    idx = (t_blk * bT
           + jax.lax.broadcasted_iota(jnp.int32, (bT,), 0)).astype(
               jnp.uint32)
    cols = jax.lax.broadcasted_iota(jnp.int32, (bT, C), 1)
    est = []
    for z in range(Z):
        bk = bucket_hash(idx, c_ref[z, 0], c_ref[z, 1], C)
        sg = sign_hash(idx, c_ref[z, 2], c_ref[z, 3])
        onehot = jnp.where(cols == bk[:, None], sg[:, None], 0.0)
        # gather each position's bucket estimate: (bN, C) @ (bT, C)^T
        est.append(jax.lax.dot_general(
            qa_ref[z, :, :], onehot, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32))
    o_ref[...] = _median_rows(est)


@functools.partial(jax.jit, static_argnames=("bN", "bC", "interpret"))
def tail_fold(rows: jax.Array, positions: jax.Array, tail: jax.Array,
              coeffs: jax.Array, *, bN: int = 256, bC: int = 256,
              interpret: bool | None = None) -> jax.Array:
    """Accumulate ``rows`` (N, D) at absolute ``positions`` (N,) int32
    into ``tail`` (Z, C, D) f32.  Returns the new (Z, C, D) table.
    D is the flattened feature axis (K * head_dim for KV rows)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    N, D = rows.shape
    Z, C, _ = tail.shape
    bN = min(bN, N)
    bC = min(bC, C)
    padN, padC = (-N) % bN, (-C) % bC
    if padN:
        # zero rows contribute nothing, whatever their padded position
        rows = jnp.pad(rows, ((0, padN), (0, 0)))
        positions = jnp.pad(positions, (0, padN))
    if padC:
        # hashes land in [0, C): padded columns are never hit
        tail = jnp.pad(tail, ((0, 0), (0, padC), (0, 0)))
    Cp = C + padC
    nN, nC = rows.shape[0] // bN, Cp // bC
    out = pl.pallas_call(
        functools.partial(_fold_kernel, bN=bN, bC=bC, C=C, Z=Z),
        grid=(nC, nN),
        in_specs=[
            pl.BlockSpec((bN,), lambda c, n: (n,)),
            pl.BlockSpec((bN, D), lambda c, n: (n, 0)),
            pl.BlockSpec((Z, bC, D), lambda c, n: (0, c, 0)),
            pl.BlockSpec((Z, 4), lambda *_: (0, 0)),
        ],
        out_specs=pl.BlockSpec((Z, bC, D), lambda c, n: (0, c, 0)),
        out_shape=jax.ShapeDtypeStruct((Z, Cp, D), jnp.float32),
        interpret=interpret,
    )(positions, rows, tail, coeffs)
    return out[:, :C, :]


@functools.partial(jax.jit, static_argnames=("T", "bN", "bT", "interpret"))
def tail_scores(q: jax.Array, tail_k: jax.Array, coeffs: jax.Array, *,
                T: int, bN: int = 128, bT: int = 256,
                interpret: bool | None = None) -> jax.Array:
    """Median-of-rows tail score estimates: (N, T) where
    out[n, t] ~= q[n] . key_row(t) for folded positions t.  q: (N, D);
    tail_k: (Z, C, D); unscaled and unmasked — the caller applies the
    softmax scale and the fold_base live mask."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    N, D = q.shape
    Z, C, _ = tail_k.shape
    bN = min(bN, N)
    bT = min(bT, T)
    padN, padT = (-N) % bN, (-T) % bT
    if padN:
        q = jnp.pad(q, ((0, padN), (0, 0)))
    Tp = T + padT
    nN, nT = q.shape[0] // bN, Tp // bT
    out = pl.pallas_call(
        functools.partial(_scores_kernel, bN=bN, bT=bT, C=C, Z=Z),
        grid=(nN, nT),
        in_specs=[
            pl.BlockSpec((bN, D), lambda n, t: (n, 0)),
            pl.BlockSpec((Z, C, D), lambda *_: (0, 0, 0)),
            pl.BlockSpec((Z, 4), lambda *_: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bN, bT), lambda n, t: (n, t)),
        out_shape=jax.ShapeDtypeStruct((q.shape[0], Tp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((Z, bN, C), jnp.float32)],
        interpret=interpret,
    )(q, tail_k, coeffs)
    return out[:N, :T]
