"""jit'd public wrappers for the sketching kernels.

On TPU the Pallas kernels run compiled (interpret=False); on this CPU
container they run in interpret mode, which executes the same kernel body
per grid cell in Python — bit-identical block semantics, usable for
correctness validation.  ``use_pallas=False`` falls back to the jnp oracle
(the fast path on CPU and the reference everywhere).
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.count_sketch import count_sketch as _cs_pallas
from repro.kernels.unsketch import unsketch as _un_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def count_sketch_op(x: jax.Array, h: jax.Array, s: jax.Array, J: int,
                    use_pallas: bool | None = None) -> jax.Array:
    """x: (B, I) -> (B, J)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return _cs_pallas(x, h, s, J, interpret=not _on_tpu())
    return ref.count_sketch_ref(x, h, s, J)


def unsketch_op(y: jax.Array, h: jax.Array, s: jax.Array,
                use_pallas: bool | None = None) -> jax.Array:
    """y: (B, J) -> (B, I)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return _un_pallas(y, h, s, interpret=not _on_tpu())
    return ref.unsketch_ref(y, h, s)
