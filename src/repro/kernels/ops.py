"""jit'd public wrappers for the sketching kernels.

The Pallas kernels auto-detect the backend (``interpret=None`` -> compiled
on TPU, interpret mode elsewhere), so call sites never pass interpret
flags.  ``use_pallas=None`` additionally picks the implementation: the
Pallas kernel on TPU, the pure-jnp oracle everywhere else (the fast path
on CPU and the reference everywhere).  ``use_pallas=True`` off-TPU runs
the kernel body in interpret mode — bit-identical block semantics, used
by the validation tests.
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.count_sketch import count_sketch as _cs_pallas
from repro.kernels.sketch_update import sketch_update as _su_pallas
from repro.kernels.unsketch import unsketch as _un_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def count_sketch_op(x: jax.Array, h: jax.Array, s: jax.Array, J: int,
                    use_pallas: bool | None = None) -> jax.Array:
    """x: (B, I) -> (B, J)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return _cs_pallas(x, h, s, J)
    return ref.count_sketch_ref(x, h, s, J)


def unsketch_op(y: jax.Array, h: jax.Array, s: jax.Array,
                use_pallas: bool | None = None) -> jax.Array:
    """y: (B, J) -> (B, I)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return _un_pallas(y, h, s)
    return ref.unsketch_ref(y, h, s)


def sketch_update_op(g: jax.Array, m_table: jax.Array, v_table: jax.Array,
                     coeffs_m: jax.Array, coeffs_v: jax.Array, *,
                     b1: float, b2: float,
                     use_pallas: bool | None = None):
    """Fused sketched-moment update-retrieve for one flat gradient leaf.
    Returns (new_m, new_v, m_hat, v_hat) — see kernels/sketch_update.py."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return _su_pallas(g, m_table, v_table, coeffs_m, coeffs_v,
                          b1=b1, b2=b2)
    return ref.sketch_update_ref(g, m_table, v_table, coeffs_m, coeffs_v,
                                 b1, b2)
