"""jit'd public wrappers for the sketching kernels.

The Pallas kernels auto-detect the backend (``interpret=None`` -> compiled
on TPU, interpret mode elsewhere), so call sites never pass interpret
flags.  ``use_pallas=None`` additionally picks the implementation: the
Pallas kernel on TPU, the pure-jnp oracle everywhere else (the fast path
on CPU and the reference everywhere).  ``use_pallas=True`` off-TPU runs
the kernel body in interpret mode — bit-identical block semantics, used
by the validation tests.
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.count_sketch import count_sketch as _cs_pallas
from repro.kernels.paged_attention import paged_attention as _pa_pallas
from repro.kernels.sketch_update import sketch_update as _su_pallas
from repro.kernels.unsketch import unsketch as _un_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_use_pallas() -> bool:
    """The backend auto-detect every op's ``use_pallas=None`` resolves
    to: Pallas kernels on TPU, jnp oracles elsewhere.  Exposed so the
    serve scheduler can resolve ``ServeConfig.paged_kernels=None`` once
    and bake a static choice into its compiled chunks."""
    return _on_tpu()


def count_sketch_op(x: jax.Array, h: jax.Array, s: jax.Array, J: int,
                    use_pallas: bool | None = None) -> jax.Array:
    """x: (B, I) -> (B, J)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return _cs_pallas(x, h, s, J)
    return ref.count_sketch_ref(x, h, s, J)


def unsketch_op(y: jax.Array, h: jax.Array, s: jax.Array,
                use_pallas: bool | None = None) -> jax.Array:
    """y: (B, J) -> (B, I)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return _un_pallas(y, h, s)
    return ref.unsketch_ref(y, h, s)


def sketch_update_op(g: jax.Array, m_table: jax.Array, v_table: jax.Array,
                     coeffs_m: jax.Array, coeffs_v: jax.Array, *,
                     b1: float, b2: float,
                     use_pallas: bool | None = None):
    """Fused sketched-moment update-retrieve for one flat gradient leaf.
    Returns (new_m, new_v, m_hat, v_hat) — see kernels/sketch_update.py."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return _su_pallas(g, m_table, v_table, coeffs_m, coeffs_v,
                          b1=b1, b2=b2)
    return ref.sketch_update_ref(g, m_table, v_table, coeffs_m, coeffs_v,
                                 b1, b2)


def paged_attention_op(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                       tables: jax.Array, start: jax.Array,
                       fold_base: jax.Array,
                       use_pallas: bool | None = None):
    """Flash-decode paged attention statistics (kernels/paged_attention.py).
    q: (B, Sq, K, R, hd); pools (NB, bs, K, hd); tables (B, nb) int32;
    start/fold_base (B,) int32.  Returns f32 (m, l, acc):
    (B, K, R, Sq) x2 and (B, K, R, Sq, hd)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return _pa_pallas(q, k_pool, v_pool, tables, start, fold_base)
    return ref.paged_attention_ref(q, k_pool, v_pool, tables, start,
                                   fold_base)
