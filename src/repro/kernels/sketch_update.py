"""Pallas TPU kernel: fused sketched-moment update-retrieve.

The sketched optimizer (repro/sketch/optimizer.py) keeps AdamW's (m, v) in
count-sketch / count-min tables.  Per leaf and step it needs

  new_m = b1 * m_table + (1-b1) * CS(g)          scatter-accumulate
  new_v = b2 * v_table + (1-b2) * CMS(g^2)
  m_hat[i] = median_r  s_r(i) * new_m[r, h_r(i)]  gather-estimate
  v_hat[i] = min_r     new_v[r, h2_r(i)]

TPUs have no efficient random scatter/gather, so both halves reuse the
signed-one-hot MXU formulation of kernels/count_sketch.py: each (bI, bC)
one-hot tile is built in VMEM from hashes evaluated ON THE FLY (uint32
multiply-add + murmur finalize from sketch/hashing.py — tabulated hashes
would cost 8 bytes/element/row and erase the memory win) and contracted on
the MXU.  Dense (m, v) never exist; HBM traffic per step is
O(n + rows*cols), tables touched once per pass.

The op is one fused update-retrieve: a scatter-accumulate pass over grid
(C/bC, I/bI) (reduction axis innermost so table tiles stay resident in
VMEM), then a gather-estimate pass over grid (I/bI, C/bC) (each row's
single hit lands in exactly one C block, so accumulation over C blocks is
exact; the median/min combine runs in-kernel at the last C block via a
static odd-even sorting network over the rows).  The retrieve reads the
freshly written tables — the strict accumulate->query dependency makes a
single-grid formulation impossible without violating Pallas's
consecutive-output-revisit rule.

``interpret=None`` auto-detects: compiled on TPU, interpret elsewhere.
``kernels/ref.py:sketch_update_ref`` is the pure-jnp oracle (bit-matching
hash arithmetic — both paths share sketch/hashing.py).
"""
from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.sketch.hashing import bucket_hash, sign_hash


def _median_rows(rows: List[jax.Array]) -> jax.Array:
    """Median across a static list of equal-shape vectors via an odd-even
    transposition network (TPU-safe: only elementwise min/max)."""
    rows = list(rows)
    R = len(rows)
    for p in range(R):
        for j in range(p % 2, R - 1, 2):
            lo = jnp.minimum(rows[j], rows[j + 1])
            hi = jnp.maximum(rows[j], rows[j + 1])
            rows[j], rows[j + 1] = lo, hi
    if R % 2:
        return rows[R // 2]
    return 0.5 * (rows[R // 2 - 1] + rows[R // 2])


def _acc_kernel(g_ref, m_ref, v_ref, cm_ref, cv_ref, om_ref, ov_ref, *,
                bI: int, bC: int, C: int, R: int, b1: float, b2: float):
    i_blk = pl.program_id(1)

    @pl.when(i_blk == 0)
    def _init():
        om_ref[...] = b1 * m_ref[...]
        ov_ref[...] = b2 * v_ref[...]

    idx = (i_blk * bI
           + jax.lax.broadcasted_iota(jnp.int32, (bI,), 0)).astype(jnp.uint32)
    g = g_ref[...].astype(jnp.float32)
    c0 = pl.program_id(0) * bC
    cols = c0 + jax.lax.broadcasted_iota(jnp.int32, (bI, bC), 1)
    for r in range(R):
        bk = bucket_hash(idx, cm_ref[r, 0], cm_ref[r, 1], C)
        sg = sign_hash(idx, cm_ref[r, 2], cm_ref[r, 3])
        onehot = jnp.where(cols == bk[:, None], sg[:, None], 0.0)
        om_ref[r:r + 1, :] += (1.0 - b1) * jax.lax.dot(
            g[None, :], onehot, preferred_element_type=jnp.float32)
        bkv = bucket_hash(idx, cv_ref[r, 0], cv_ref[r, 1], C)
        onehot_v = jnp.where(cols == bkv[:, None], 1.0, 0.0)
        ov_ref[r:r + 1, :] += (1.0 - b2) * jax.lax.dot(
            (g * g)[None, :], onehot_v, preferred_element_type=jnp.float32)


def _ret_kernel(m_ref, v_ref, cm_ref, cv_ref, mh_ref, vh_ref, em_ref, ev_ref,
                *, bI: int, bC: int, C: int, R: int, nC: int):
    i_blk = pl.program_id(0)
    c_blk = pl.program_id(1)

    @pl.when(c_blk == 0)
    def _init():
        em_ref[...] = jnp.zeros_like(em_ref)
        ev_ref[...] = jnp.zeros_like(ev_ref)

    idx = (i_blk * bI
           + jax.lax.broadcasted_iota(jnp.int32, (bI,), 0)).astype(jnp.uint32)
    c0 = c_blk * bC
    cols = c0 + jax.lax.broadcasted_iota(jnp.int32, (bI, bC), 1)
    for r in range(R):
        bk = bucket_hash(idx, cm_ref[r, 0], cm_ref[r, 1], C)
        sg = sign_hash(idx, cm_ref[r, 2], cm_ref[r, 3])
        onehot = jnp.where(cols == bk[:, None], sg[:, None], 0.0)
        em_ref[r:r + 1, :] += jax.lax.dot_general(
            m_ref[r:r + 1, :], onehot, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        bkv = bucket_hash(idx, cv_ref[r, 0], cv_ref[r, 1], C)
        onehot_v = jnp.where(cols == bkv[:, None], 1.0, 0.0)
        ev_ref[r:r + 1, :] += jax.lax.dot_general(
            v_ref[r:r + 1, :], onehot_v, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(c_blk == nC - 1)
    def _emit():
        em = em_ref[...]
        ev = ev_ref[...]
        mh_ref[...] = _median_rows([em[r] for r in range(R)])
        vh_ref[...] = functools.reduce(jnp.minimum,
                                       [ev[r] for r in range(R)])


@functools.partial(jax.jit, static_argnames=("b1", "b2", "bI", "bC",
                                             "interpret"))
def sketch_update(g: jax.Array, m_table: jax.Array, v_table: jax.Array,
                  coeffs_m: jax.Array, coeffs_v: jax.Array, *,
                  b1: float = 0.9, b2: float = 0.95,
                  bI: int = 512, bC: int = 256,
                  interpret: bool | None = None):
    """Fused moment update + estimate for one flat gradient leaf.

    g: (n,) — any float dtype, accumulated in f32.
    m_table / v_table: (R, C) f32; coeffs_*: (R, 4) uint32.
    Returns (new_m (R, C), new_v (R, C), m_hat (n,), v_hat (n,)).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = g.shape[0]
    R, C = m_table.shape
    bI = min(bI, n)
    bC = min(bC, C)
    padI, padC = (-n) % bI, (-C) % bC
    if padI:
        g = jnp.pad(g, (0, padI))        # zero grads: no-op contributions
    if padC:
        m_table = jnp.pad(m_table, ((0, 0), (0, padC)))
        v_table = jnp.pad(v_table, ((0, 0), (0, padC)))
    Cp = C + padC
    nI, nC = g.shape[0] // bI, Cp // bC

    coeff_spec = pl.BlockSpec((R, 4), lambda *_: (0, 0))
    new_m, new_v = pl.pallas_call(
        functools.partial(_acc_kernel, bI=bI, bC=bC, C=C, R=R, b1=b1, b2=b2),
        grid=(nC, nI),
        in_specs=[
            pl.BlockSpec((bI,), lambda c, i: (i,)),
            pl.BlockSpec((R, bC), lambda c, i: (0, c)),
            pl.BlockSpec((R, bC), lambda c, i: (0, c)),
            coeff_spec, coeff_spec,
        ],
        out_specs=[pl.BlockSpec((R, bC), lambda c, i: (0, c)),
                   pl.BlockSpec((R, bC), lambda c, i: (0, c))],
        out_shape=[jax.ShapeDtypeStruct((R, Cp), jnp.float32),
                   jax.ShapeDtypeStruct((R, Cp), jnp.float32)],
        interpret=interpret,
    )(g, m_table, v_table, coeffs_m, coeffs_v)

    m_hat, v_hat = pl.pallas_call(
        functools.partial(_ret_kernel, bI=bI, bC=bC, C=C, R=R, nC=nC),
        grid=(nI, nC),
        in_specs=[
            pl.BlockSpec((R, bC), lambda i, c: (0, c)),
            pl.BlockSpec((R, bC), lambda i, c: (0, c)),
            coeff_spec, coeff_spec,
        ],
        out_specs=[pl.BlockSpec((bI,), lambda i, c: (i,)),
                   pl.BlockSpec((bI,), lambda i, c: (i,))],
        out_shape=[jax.ShapeDtypeStruct((g.shape[0],), jnp.float32),
                   jax.ShapeDtypeStruct((g.shape[0],), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((R, bI), jnp.float32),
                        pltpu.VMEM((R, bI), jnp.float32)],
        interpret=interpret,
    )(new_m, new_v, coeffs_m, coeffs_v)

    return new_m[:, :C], new_v[:, :C], m_hat[:n], v_hat[:n]
