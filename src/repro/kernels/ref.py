"""Pure-jnp oracles for the Pallas kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sketch.csvec import CSVec, accumulate, query


def count_sketch_ref(x: jax.Array, h: jax.Array, s: jax.Array,
                     J: int) -> jax.Array:
    """Batched signed bucket-accumulate.
    x: (B, I); h: (I,) int32 in [0, J); s: (I,) +-1.  -> (B, J)."""
    onehot = jax.nn.one_hot(h, J, dtype=x.dtype) * s[:, None].astype(x.dtype)
    return x @ onehot


def unsketch_ref(y: jax.Array, h: jax.Array, s: jax.Array) -> jax.Array:
    """Batched decompress: out[b, i] = s[i] * y[b, h[i]].
    y: (B, J); h: (I,); s: (I,).  -> (B, I)."""
    return y[:, h] * s[None, :].astype(y.dtype)


def kv_tail_fold_ref(rows: jax.Array, positions: jax.Array,
                     tail: jax.Array, coeffs: jax.Array) -> jax.Array:
    """Oracle for kernels/kv_sketch.py:tail_fold, delegating to the serve
    math (serve/kv_sketch.py:fold_rows) so the kernel is checked against
    exactly what the engine computes.  rows: (N, D); positions: (N,);
    tail: (Z, C, D).  -> new (Z, C, D) table."""
    from repro.serve.kv_sketch import fold_rows
    C = tail.shape[1]
    # fold_rows speaks (B, n, K, hd): view D as K with hd == 1
    r4 = rows[None, :, :, None]
    acc = fold_rows(r4, r4, positions, coeffs, C)["k"][0, :, :, :, 0]
    return tail + acc


def kv_tail_scores_ref(q: jax.Array, tail_k: jax.Array, coeffs: jax.Array,
                       T: int) -> jax.Array:
    """Oracle for kernels/kv_sketch.py:tail_scores via the serve path's
    precomputed signed position one-hot (serve/kv_sketch.py:pos_onehot —
    same in-graph hashes as the kernel's on-the-fly tiles).
    q: (N, D); tail_k: (Z, C, D).  -> (N, T) median-of-rows estimates."""
    from repro.serve.kv_sketch import pos_onehot
    C = tail_k.shape[1]
    onehot = pos_onehot(coeffs, T, C)                       # (Z, T, C)
    qa = jnp.einsum("nd,zcd->znc", q.astype(jnp.float32),
                    tail_k.astype(jnp.float32))
    est = jnp.einsum("znc,ztc->znt", qa, onehot)
    return jnp.median(est, axis=0)


def sketch_update_ref(g: jax.Array, m_table: jax.Array, v_table: jax.Array,
                      coeffs_m: jax.Array, coeffs_v: jax.Array,
                      b1: float, b2: float):
    """Fused optimizer update-retrieve on sketched (m, v) moments.

    g: (n,) f32 gradient; m_table/v_table: (R, C) count-sketch/count-min
    tables; coeffs: (R, 4) uint32 hash coefficients (sketch/hashing.py).

      new_m = b1 * m_table + (1-b1) * CS(g)        (signed)
      new_v = b2 * v_table + (1-b2) * CMS(g^2)     (unsigned)
      m_hat = median-of-rows query of new_m at all n coordinates
      v_hat = min-of-rows query of new_v

    Returns (new_m, new_v, m_hat, v_hat).  Expressed through the CSVec
    container ops so the oracle and repro.sketch share one copy of the
    accumulate/query math."""
    n = g.shape[0]
    gf = g.astype(jnp.float32)
    cs_g = accumulate(CSVec(table=jnp.zeros_like(m_table), coeffs=coeffs_m,
                            d=n, signed=True), gf)
    cs_g2 = accumulate(CSVec(table=jnp.zeros_like(v_table), coeffs=coeffs_v,
                             d=n, signed=False), gf * gf)
    new_m = b1 * m_table + (1.0 - b1) * cs_g.table
    new_v = b2 * v_table + (1.0 - b2) * cs_g2.table
    idx = jnp.arange(n, dtype=jnp.int32)
    m_hat = query(CSVec(table=new_m, coeffs=coeffs_m, d=n, signed=True),
                  idx)
    v_hat = query(CSVec(table=new_v, coeffs=coeffs_v, d=n, signed=False),
                  idx)
    return new_m, new_v, m_hat, v_hat
