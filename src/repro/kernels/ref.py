"""Pure-jnp oracles for the Pallas kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def count_sketch_ref(x: jax.Array, h: jax.Array, s: jax.Array,
                     J: int) -> jax.Array:
    """Batched signed bucket-accumulate.
    x: (B, I); h: (I,) int32 in [0, J); s: (I,) +-1.  -> (B, J)."""
    onehot = jax.nn.one_hot(h, J, dtype=x.dtype) * s[:, None].astype(x.dtype)
    return x @ onehot


def unsketch_ref(y: jax.Array, h: jax.Array, s: jax.Array) -> jax.Array:
    """Batched decompress: out[b, i] = s[i] * y[b, h[i]].
    y: (B, J); h: (I,); s: (I,).  -> (B, I)."""
    return y[:, h] * s[None, :].astype(y.dtype)
