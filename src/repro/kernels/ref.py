"""Pure-jnp oracles for the Pallas kernels."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.sketch.csvec import CSVec, accumulate, query


def count_sketch_ref(x: jax.Array, h: jax.Array, s: jax.Array,
                     J: int) -> jax.Array:
    """Batched signed bucket-accumulate.
    x: (B, I); h: (I,) int32 in [0, J); s: (I,) +-1.  -> (B, J)."""
    onehot = jax.nn.one_hot(h, J, dtype=x.dtype) * s[:, None].astype(x.dtype)
    return x @ onehot


def unsketch_ref(y: jax.Array, h: jax.Array, s: jax.Array) -> jax.Array:
    """Batched decompress: out[b, i] = s[i] * y[b, h[i]].
    y: (B, J); h: (I,); s: (I,).  -> (B, I)."""
    return y[:, h] * s[None, :].astype(y.dtype)


def kv_tail_fold_ref(rows: jax.Array, positions: jax.Array,
                     tail: jax.Array, coeffs: jax.Array) -> jax.Array:
    """Oracle for kernels/kv_sketch.py:tail_fold, delegating to the serve
    math (serve/kv_sketch.py:fold_rows) so the kernel is checked against
    exactly what the engine computes.  rows: (N, D); positions: (N,);
    tail: (Z, C, D).  -> new (Z, C, D) table."""
    from repro.serve.kv_sketch import fold_rows
    C = tail.shape[1]
    # fold_rows speaks (B, n, K, hd): view D as K with hd == 1
    r4 = rows[None, :, :, None]
    acc = fold_rows(r4, r4, positions, coeffs, C)["k"][0, :, :, :, 0]
    return tail + acc


def kv_tail_scores_ref(q: jax.Array, tail_k: jax.Array, coeffs: jax.Array,
                       T: int) -> jax.Array:
    """Oracle for kernels/kv_sketch.py:tail_scores via the serve path's
    precomputed signed position one-hot (serve/kv_sketch.py:pos_onehot —
    same in-graph hashes as the kernel's on-the-fly tiles).
    q: (N, D); tail_k: (Z, C, D).  -> (N, T) median-of-rows estimates."""
    from repro.serve.kv_sketch import pos_onehot
    C = tail_k.shape[1]
    onehot = pos_onehot(coeffs, T, C)                       # (Z, T, C)
    qa = jnp.einsum("nd,zcd->znc", q.astype(jnp.float32),
                    tail_k.astype(jnp.float32))
    est = jnp.einsum("znc,ztc->znt", qa, onehot)
    return jnp.median(est, axis=0)


def paged_attention_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                        tables: jax.Array, start: jax.Array,
                        fold_base: jax.Array):
    """Oracle for kernels/paged_attention.py: the same online-softmax
    block walk in plain jnp — a lax.scan over the slot's logical blocks,
    fetching each physical block through the table (dead entries >= NB
    clamp the fetch and mask the whole block), with op-for-op the
    kernel's update equations and dtypes, so interpret mode reproduces it
    bitwise.  Shapes/returns match ``paged_attention``: q (B,Sq,K,R,hd),
    pools (NB,bs,K,hd), tables (B,nb) int32, start/fold_base (B,) ->
    f32 (m, l, acc): (B,K,R,Sq) x2 and (B,K,R,Sq,hd)."""
    B, Sq, K, R, hd = q.shape
    NB, bs = k_pool.shape[0], k_pool.shape[1]
    nb_slot = tables.shape[1]
    NQ = R * Sq
    scale = 1.0 / math.sqrt(hd)
    qt = q.transpose(0, 2, 3, 1, 4).reshape(B, K, NQ, hd)
    st = start.astype(jnp.int32)
    fb = fold_base.astype(jnp.int32)
    qi = jax.lax.broadcasted_iota(jnp.int32, (NQ, bs), 0) % Sq

    def block(carry, j):
        m, l, acc = carry                # (B,K,NQ) x2, (B,K,NQ,hd)
        entry = tables[:, j]             # (B,)
        valid = entry < NB
        kj = jnp.take(k_pool, jnp.minimum(entry, NB - 1), axis=0)
        vj = jnp.take(v_pool, jnp.minimum(entry, NB - 1), axis=0)
        kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (NQ, bs), 1)
        live = ((kpos[None] <= st[:, None, None] + qi[None])
                & (kpos[None] >= fb[:, None, None])
                & valid[:, None, None])  # (B, NQ, bs)
        live = live[:, None]             # (B, 1, NQ, bs)
        s = jnp.einsum("bknh,bskh->bkns", qt, kj,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(live, s, -1e30)
        m_cur = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.where(live, jnp.exp(s - m_cur[..., None]), 0.0)
        corr = jnp.exp(m - m_cur)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkns,bskh->bknh", p.astype(vj.dtype), vj,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (m_cur, l, acc), None

    m0 = jnp.full((B, K, NQ), -1e30, jnp.float32)
    l0 = jnp.zeros((B, K, NQ), jnp.float32)
    a0 = jnp.zeros((B, K, NQ, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(block, (m0, l0, a0),
                                  jnp.arange(nb_slot, dtype=jnp.int32))
    return (m.reshape(B, K, R, Sq), l.reshape(B, K, R, Sq),
            acc.reshape(B, K, R, Sq, hd))


def sketch_update_ref(g: jax.Array, m_table: jax.Array, v_table: jax.Array,
                      coeffs_m: jax.Array, coeffs_v: jax.Array,
                      b1: float, b2: float):
    """Fused optimizer update-retrieve on sketched (m, v) moments.

    g: (n,) f32 gradient; m_table/v_table: (R, C) count-sketch/count-min
    tables; coeffs: (R, 4) uint32 hash coefficients (sketch/hashing.py).

      new_m = b1 * m_table + (1-b1) * CS(g)        (signed)
      new_v = b2 * v_table + (1-b2) * CMS(g^2)     (unsigned)
      m_hat = median-of-rows query of new_m at all n coordinates
      v_hat = min-of-rows query of new_v

    Returns (new_m, new_v, m_hat, v_hat).  Expressed through the CSVec
    container ops so the oracle and repro.sketch share one copy of the
    accumulate/query math."""
    n = g.shape[0]
    gf = g.astype(jnp.float32)
    cs_g = accumulate(CSVec(table=jnp.zeros_like(m_table), coeffs=coeffs_m,
                            d=n, signed=True), gf)
    cs_g2 = accumulate(CSVec(table=jnp.zeros_like(v_table), coeffs=coeffs_v,
                             d=n, signed=False), gf * gf)
    new_m = b1 * m_table + (1.0 - b1) * cs_g.table
    new_v = b2 * v_table + (1.0 - b2) * cs_g2.table
    idx = jnp.arange(n, dtype=jnp.int32)
    m_hat = query(CSVec(table=new_m, coeffs=coeffs_m, d=n, signed=True),
                  idx)
    v_hat = query(CSVec(table=new_v, coeffs=coeffs_v, d=n, signed=False),
                  idx)
    return new_m, new_v, m_hat, v_hat
