"""Training loop with the production-survival features:

  * checkpoint cadence with atomic writes + resume-from-LATEST (bitwise:
    the data pipeline is stateless-seeded by step, optimizer state is saved)
  * straggler monitor: per-step wall-time EMA; steps slower than
    ``straggler_factor`` x EMA are logged with their step id (on real
    multi-host deployments this feeds host-eviction; here it drives the
    log + test hooks)
  * optional FCS gradient compression (error-feedback state is part of the
    checkpoint, so restarts preserve convergence behaviour)
  * optional sketched optimizer state (cfg.sketch.opt_state_ratio > 0):
    AdamW moments live in count-sketch tables (repro.sketch), shrinking
    optimizer memory to O(numel/ratio); the state pytree checkpoints and
    resumes like the dense one.
  * optional loss-spike skip: steps whose loss is > spike_factor x EMA are
    applied with zero LR (gradient skipped), a common large-run guard.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.train import checkpoint as ckpt_lib
from repro.train import data as data_lib
from repro.train.grad_compress import (init_error_feedback,
                                       make_compressed_train_step)
from repro.train.optimizer import make_optimizer


@dataclass
class TrainHistory:
    losses: List[float] = field(default_factory=list)
    step_times: List[float] = field(default_factory=list)
    stragglers: List[int] = field(default_factory=list)
    skipped: List[int] = field(default_factory=list)


def train(cfg: ModelConfig, *, steps: int, batch: int, seq: int,
          lr: float = 3e-4, seed: int = 0,
          ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
          resume: bool = False, grad_compression: Optional[bool] = None,
          straggler_factor: float = 3.0, spike_factor: float = 4.0,
          log_every: int = 10, crash_at_step: Optional[int] = None,
          log_fn: Callable[[str], None] = print) -> TrainHistory:
    """Single-process trainer (tests/examples scale; the distributed path
    shares the same step functions via launch/train.py)."""
    compress = (cfg.sketch.grad_compression if grad_compression is None
                else grad_compression)
    key = jax.random.PRNGKey(seed)
    params = M.init_params(key, cfg)
    opt_init, opt_update = make_optimizer(cfg, lr=lr)
    opt = opt_init(params)
    if cfg.sketch.opt_state_ratio > 0:
        from repro.sketch.optimizer import moment_state_bytes
        b = moment_state_bytes(opt)
        shrink = (b["sketched_dense_equiv"] / b["sketched"]
                  if b["sketched"] else 1.0)
        log_fn(f"[opt] sketched moments: {b['sketched']} B sketched "
               f"({shrink:.1f}x vs dense) + {b['dense']} B dense leaves")
    ef = init_error_feedback(params, cfg.sketch.grad_hash_ratio,
                             cfg.sketch.seed) if compress else None
    start_step = 0

    if resume and ckpt_dir and ckpt_lib.latest_step(ckpt_dir) is not None:
        state_like = {"params": params, "opt": opt, "ef": ef}
        step_loaded, state = ckpt_lib.restore(ckpt_dir, state_like)
        params, opt, ef = state["params"], state["opt"], state["ef"]
        start_step = step_loaded
        log_fn(f"[resume] from step {start_step}")

    if compress:
        grad_step = make_compressed_train_step(cfg)
    base_step = M.make_train_step(cfg)

    @jax.jit
    def step_fn(params, opt, ef, batch_d, skip, step_idx):
        if compress:
            loss, grads, ef = grad_step(params, ef, batch_d, step_idx)
        else:
            loss, grads = base_step(params, batch_d)
        new_params, new_opt = opt_update(grads, opt, params)
        # loss-spike guard: keep old params/opt when skipping
        new_params = jax.tree.map(
            lambda np_, p: jnp.where(skip, p, np_), new_params, params)
        new_opt = jax.tree.map(
            lambda no, o: jnp.where(skip, o, no), new_opt, opt)
        return loss, new_params, new_opt, ef

    hist = TrainHistory()
    ema_time = None
    ema_loss = None
    for step in range(start_step, steps):
        if crash_at_step is not None and step == crash_at_step:
            raise RuntimeError(f"injected crash at step {step}")
        bd = data_lib.make_batch(cfg, step, batch, seq, seed)
        t0 = time.time()
        loss, params, opt, ef = step_fn(params, opt, ef, bd,
                                        jnp.bool_(False), jnp.int32(step))
        loss = float(loss)
        dt = time.time() - t0
        hist.losses.append(loss)
        hist.step_times.append(dt)
        if ema_time is not None and dt > straggler_factor * ema_time:
            hist.stragglers.append(step)
            log_fn(f"[straggler] step {step}: {dt:.3f}s vs EMA "
                   f"{ema_time:.3f}s")
        ema_time = dt if ema_time is None else 0.9 * ema_time + 0.1 * dt
        if ema_loss is not None and loss > spike_factor * max(ema_loss, 1e-6):
            hist.skipped.append(step)
        ema_loss = loss if ema_loss is None else 0.9 * ema_loss + 0.1 * loss
        if step % log_every == 0:
            log_fn(f"step {step:5d} loss {loss:.4f} ({dt*1e3:.0f} ms)")
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            ckpt_lib.save(ckpt_dir, step + 1,
                          {"params": params, "opt": opt, "ef": ef},
                          extra={"cfg": cfg.name})
    return hist
