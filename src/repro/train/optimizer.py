"""AdamW in pure pytrees (no optax in this deployment).

Optimizer state is f32 (m, v) and inherits each parameter's sharding; under
the FSDP strategies the states are therefore already fully sharded
(ZeRO-3-equivalent).  ``adamw_update`` is functional and jit-friendly.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array           # () int32
    m: Any                    # f32 pytree like params
    v: Any                    # f32 pytree like params


def adamw_init(params: Any) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def adamw_update(grads: Any, state: AdamWState, params: Any,
                 lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.01,
                 ) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m = b1 * m + (1.0 - b1) * gf
        v = b2 * v + (1.0 - b2) * jnp.square(gf)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)


def sgd_update(grads: Any, params: Any, lr: float = 1e-2) -> Any:
    return jax.tree.map(
        lambda p, g: (p.astype(jnp.float32)
                      - lr * g.astype(jnp.float32)).astype(p.dtype),
        params, grads)
