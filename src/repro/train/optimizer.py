"""AdamW in pure pytrees (no optax in this deployment).

Optimizer state is f32 (m, v) and inherits each parameter's sharding; under
the FSDP strategies the states are therefore already fully sharded
(ZeRO-3-equivalent).  ``adamw_update`` is functional and jit-friendly.

``make_optimizer`` is the config-driven entry point: with
``cfg.sketch.opt_state_ratio > 0`` it returns the sketched AdamW from
repro.sketch.optimizer (moments in count-sketch tables, O(numel/ratio)
state); otherwise the dense AdamW below.  Both sides share the
(init, update) protocol: ``init(params) -> state`` and
``update(grads, state, params) -> (params, state)``.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array           # () int32
    m: Any                    # f32 pytree like params
    v: Any                    # f32 pytree like params


def adamw_init(params: Any) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def adamw_leaf_update(p, g, m, v, *, lr, b1, b2, eps, weight_decay,
                      bc1, bc2):
    """One AdamW leaf: returns (new_p, new_m, new_v).  The single source
    of the dense moment math — the sketched optimizer's dense leaves
    (repro.sketch.optimizer) reuse it."""
    gf = g.astype(jnp.float32)
    m = b1 * m + (1.0 - b1) * gf
    v = b2 * v + (1.0 - b2) * jnp.square(gf)
    delta = (m / bc1) / (jnp.sqrt(v / bc2) + eps) \
        + weight_decay * p.astype(jnp.float32)
    return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v


def adamw_update(grads: Any, state: AdamWState, params: Any,
                 lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.01,
                 ) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        return adamw_leaf_update(p, g, m, v, lr=lr, b1=b1, b2=b2, eps=eps,
                                 weight_decay=weight_decay, bc1=bc1,
                                 bc2=bc2)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)


def make_optimizer(cfg, lr: float = 3e-4
                   ) -> Tuple[Callable[[Any], Any],
                              Callable[[Any, Any, Any], Tuple[Any, Any]]]:
    """(init, update) for the config: sketched AdamW when
    ``cfg.sketch.opt_state_ratio > 0``, dense AdamW otherwise."""
    sk = cfg.sketch
    if sk.opt_state_ratio > 0:
        from repro.sketch.optimizer import (sketched_adamw_init,
                                            sketched_adamw_update)

        def init(params):
            return sketched_adamw_init(
                params, ratio=sk.opt_state_ratio, rows=sk.opt_state_rows,
                min_elems=sk.opt_state_min_elems, seed=sk.seed)

        def update(grads, state, params):
            return sketched_adamw_update(grads, state, params, lr=lr)

        return init, update

    def update(grads, state, params):
        return adamw_update(grads, state, params, lr=lr)

    return adamw_init, update


def sgd_update(grads: Any, params: Any, lr: float = 1e-2) -> Any:
    return jax.tree.map(
        lambda p, g: (p.astype(jnp.float32)
                      - lr * g.astype(jnp.float32)).astype(p.dtype),
        params, grads)
