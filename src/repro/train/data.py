"""Deterministic synthetic LM data pipeline.

Stateless seeding: batch(step) is a pure function of (seed, step, shape), so
any host can regenerate any shard after a restart or host replacement —
no data-state handoff, which is the straggler/elasticity story for the
input pipeline.  Token streams are Zipf-ish over the vocab with a
repetition structure so models have something learnable (copy task:
labels = next token of a periodic sequence + noise tokens).
"""
from __future__ import annotations

from typing import Dict, Iterator

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def make_batch(cfg: ModelConfig, step: int, batch: int, seq: int,
               seed: int = 0) -> Dict[str, jax.Array]:
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k1, k2, k3 = jax.random.split(key, 3)
    V = cfg.vocab_size
    # learnable structure: short periodic motifs + uniform noise
    period = 8
    motif = jax.random.randint(k1, (batch, period), 0, V)
    reps = seq // period + 2
    stream = jnp.tile(motif, (1, reps))[:, :seq + 1]
    noise = jax.random.randint(k2, stream.shape, 0, V)
    is_noise = jax.random.bernoulli(k3, 0.1, stream.shape)
    stream = jnp.where(is_noise, noise, stream)
    tokens = stream[:, :seq]
    labels = stream[:, 1:seq + 1]
    if cfg.frontend != "none":
        # stub frontend: deterministic pseudo-embeddings from token ids
        emb_key = jax.random.PRNGKey(seed + 1)
        table = jax.random.normal(emb_key, (1024, cfg.d_model), jnp.bfloat16)
        embeds = table[tokens % 1024]
        return {"embeds": embeds, "labels": labels}
    return {"tokens": tokens, "labels": labels}


def batch_iterator(cfg: ModelConfig, batch: int, seq: int, seed: int = 0,
                   start_step: int = 0) -> Iterator[Dict[str, jax.Array]]:
    step = start_step
    while True:
        yield make_batch(cfg, step, batch, seq, seed)
        step += 1
