"""FCS gradient compression with error feedback — the paper's technique as
a first-class distributed-training feature.

Cross-pod (DCN) bandwidth is the scarcest link of the 2x16x16 production
mesh.  Each pod sketches its gradient leaves with FCS, all-reduces the
J~-length sketches over the ``pod`` axis, and decompresses with the paper's
Section-4.3 rule; the local compression residual is kept as error feedback
(FetchSGD-style — count-sketched gradient aggregation is established;
Prop. 1 makes FCS a strictly-better-variance drop-in for the CS/TS there).

Leaf handling: every leaf with >= 2*ratio elements is reshaped to a 2D
tensor (numel/k, k) with k = ratio; per-mode hash lengths J_n = I_n, so the
sketch length is J~ = numel/k + k - 1 — a factor-k reduction in DCN bytes
with hash-table storage O(numel/k) (vs CS's O(numel) long pair; this is the
paper's storage argument doing real work at scale).  Small leaves pass
through uncompressed.

Implementation notes: sketch/unsketch are linear, so
  unsketch(pmean_pod(sketch(g_pod))) == unsketch(sketch(pmean_pod(g_pod)));
on a single-pod mesh the wrapper reduces to plain (sketch->unsketch) noise
injection + EF.  On the multi-pod mesh ``jax.shard_map`` over the ``pod``
axis places the all-reduce on the sketches explicitly, so the dry-run's
DCN byte count shows the compression.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M

MIN_COMPRESS_ELEMS = 1 << 16


class LeafCodec(NamedTuple):
    leaf_id: int
    I1: int
    k: int
    Jt: int
    pad: int


def _leaf_codecs(params_shape: Any, ratio: int, seed: int) -> Any:
    """One codec per compressible leaf (None for pass-through leaves)."""
    leaves, tdef = jax.tree.flatten(params_shape)
    codecs = []
    for i, leaf in enumerate(leaves):
        n = leaf.size
        if n < MIN_COMPRESS_ELEMS:
            codecs.append(None)
            continue
        k = ratio
        I1 = -(-n // k)
        pad = I1 * k - n
        codecs.append(LeafCodec(i, I1, k, I1 + k - 1, pad))
    return jax.tree.unflatten(tdef, [c if c is not None else 0
                                     for c in codecs]), codecs


def _codec_hashes(c: LeafCodec, key: jax.Array):
    """Fresh hash tables per (leaf, step), generated in-graph.

    Per-step REHASHING is essential: a fixed sketch matrix S has a fixed
    null space of dimension ~ (1 - 1/k) * n, and error feedback can never
    transmit mass stuck in null(S).  Fresh hashes each step make
    E_t[S_t^T S_t] = I, so EF drains everything.  jax.random gives fully
    independent hashes (strictly stronger than the 2-wise family the
    theory needs); nothing is stored — hashes are regenerated from
    (seed, step) on every participant identically."""
    k1, k2, k3, k4 = jax.random.split(jax.random.fold_in(key, c.leaf_id), 4)
    h1 = jax.random.randint(k1, (c.I1,), 0, c.I1)
    s1 = 1.0 - 2.0 * jax.random.randint(k2, (c.I1,), 0, 2).astype(jnp.float32)
    h2 = jax.random.randint(k3, (c.k,), 0, c.k)
    s2 = 1.0 - 2.0 * jax.random.randint(k4, (c.k,), 0, 2).astype(jnp.float32)
    return h1, s1, h2, s2


def sketch_leaf(g: jax.Array, c: LeafCodec, key: jax.Array) -> jax.Array:
    """FCS sketch of one gradient leaf: (J~,) f32."""
    h1, s1, h2, s2 = _codec_hashes(c, key)
    flat = g.reshape(-1).astype(jnp.float32)
    if c.pad:
        flat = jnp.pad(flat, (0, c.pad))
    g2 = flat.reshape(c.I1, c.k)
    pos = h1[:, None] + h2[None, :]
    val = g2 * s1[:, None] * s2[None, :]
    return jnp.zeros((c.Jt,), jnp.float32).at[pos.reshape(-1)].add(
        val.reshape(-1))


def unsketch_leaf(sk: jax.Array, c: LeafCodec, shape, dtype,
                  key: jax.Array) -> jax.Array:
    h1, s1, h2, s2 = _codec_hashes(c, key)
    pos = h1[:, None] + h2[None, :]
    est = sk[pos] * s1[:, None] * s2[None, :]
    flat = est.reshape(-1)
    if c.pad:
        flat = flat[:-c.pad]
    return flat.reshape(shape).astype(dtype)


def compress_roundtrip(g: jax.Array, ef: jax.Array, c,
                       key: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(g, ef) -> (g_hat, ef).  g_hat = unsketch(sketch(g)) is an UNBIASED
    estimate (E[S^T S] = I under fresh hashes), with collision-noise
    variance ~ (k-1)||g||^2/n per coordinate.

    Design note (validated empirically in tests/benchmarks): error
    feedback is deliberately NOT accumulated.  EF theory requires a
    contractive (biased, norm-reducing) compressor; sketch-unsketch is
    unbiased with lambda_max(S^T S) ~ 2k, so EF either stalls on the fixed
    null space (fixed hashes) or amplifies (fresh hashes).  The unbiased
    estimator + per-step rehash is the principled pairing: plain SGD
    convergence theory with (1+omega)-variance gradients applies, and
    Adam's per-coordinate normalization absorbs the variance in practice.
    The ``ef`` buffer is kept as a zeros pytree for checkpoint/API
    stability."""
    if not isinstance(c, LeafCodec):
        return g, ef
    sk = sketch_leaf(g.astype(jnp.float32), c, key)
    est = unsketch_leaf(sk, c, g.shape, jnp.float32, key)
    return est.astype(g.dtype), ef


def init_error_feedback(params: Any, ratio: int, seed: int = 0) -> Any:
    """Placeholder EF state (see compress_roundtrip: the unbiased scheme
    doesn't accumulate error; tiny zero leaves keep the checkpoint/API
    shape stable without replicated full-size buffers)."""
    leaves, tdef = jax.tree.flatten(params)
    return jax.tree.unflatten(
        tdef, [jnp.zeros((1,), jnp.float32) for _ in leaves])


# ---------------------------------------------------------------------------
# Train-step wrappers
# ---------------------------------------------------------------------------


def make_compressed_train_step(cfg: ModelConfig, multi_pod: bool = False):
    """Gradient step with FCS compression of the pod-axis reduction.

    Single-pod: grads pass through (sketch -> unsketch) + EF globally (the
    linear-equivalence note above).  Multi-pod: the loss/grad is computed
    per pod under jax.shard_map(axis_names={"pod"}) and only the sketches
    cross the DCN.
    """
    ratio = cfg.sketch.grad_hash_ratio
    seed = cfg.sketch.seed

    def apply_ef_tree(grads, ef, codecs_flat, key):
        """Compress every codec'd leaf with the per-step hash key.  The key
        is an explicit argument: it is trace-local state, and stashing it
        on the function object (the old hack) is invisible to jit retracing
        and racy under concurrent traces of the same closure."""
        gl, tdef = jax.tree.flatten(grads)
        el = jax.tree.leaves(ef)
        out_g, out_e = [], []
        for g, e, c in zip(gl, el, codecs_flat):
            if c is None:
                out_g.append(g)
                out_e.append(e)
            else:
                gh, en = compress_roundtrip(g, e, c, key)
                out_g.append(gh)
                out_e.append(en)
        return jax.tree.unflatten(tdef, out_g), jax.tree.unflatten(tdef, out_e)

    def train_step(params, ef, batch, step=0):
        pspecs = jax.eval_shape(lambda p: p, params)
        _, codecs_flat = _leaf_codecs(pspecs, ratio, seed)
        loss, grads = jax.value_and_grad(M.loss_fn)(params, batch, cfg)
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
        grads, ef = apply_ef_tree(grads, ef, codecs_flat, key)
        return loss, grads, ef

    return train_step


def make_podwise_compressed_step(cfg: ModelConfig, mesh):
    """Explicit multi-pod variant: shard_map over the pod axis so the HLO
    provably all-reduces only the sketches across pods."""
    from jax.sharding import PartitionSpec as P
    from repro.models.sharding import logical_rules
    ratio = cfg.sketch.grad_hash_ratio
    seed = cfg.sketch.seed

    def train_step(params, ef, batch, step=0):
        pspecs = jax.eval_shape(lambda p: p, params)
        _, codecs_flat = _leaf_codecs(pspecs, ratio, seed)
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)

        def per_pod(params, ef, batch):
            # inside shard_map the pod axis is Manual: re-trace the model
            # under single-pod logical rules so activation constraints
            # only reference the remaining (Auto) axes.
            from repro.launch.shardings import make_rules
            inner_rules, _ = make_rules(cfg, "train", False, False)
            with logical_rules(inner_rules):
                loss, grads = jax.value_and_grad(M.loss_fn)(params, batch,
                                                            cfg)
            gl, tdef = jax.tree.flatten(grads)
            el = jax.tree.leaves(ef)
            out_g, out_e = [], []
            for g, e, c in zip(gl, el, codecs_flat):
                if c is None:
                    out_g.append(jax.lax.pmean(g, "pod"))
                    out_e.append(e)
                else:
                    sk = sketch_leaf(g.astype(jnp.float32), c, key)
                    sk_mean = jax.lax.pmean(sk, "pod")   # DCN: J~ floats
                    gh = unsketch_leaf(sk_mean, c, g.shape, jnp.float32,
                                       key)
                    out_g.append(gh.astype(g.dtype))
                    out_e.append(e)
            loss = jax.lax.pmean(loss, "pod")
            return (loss, jax.tree.unflatten(tdef, out_g),
                    jax.tree.unflatten(tdef, out_e))

        return jax.shard_map(
            per_pod, mesh=mesh, axis_names={"pod"},
            in_specs=(P(), P(), P("pod")),
            out_specs=(P(), P(), P()),
            check_vma=False,
        )(params, ef, batch)

    return train_step
