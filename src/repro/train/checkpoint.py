"""Fault-tolerant checkpointing: atomic, mesh-agnostic, elastic.

Format: one .npz of flattened full arrays + a JSON manifest (step, config
name, tree structure).  Writes go to a temp file + os.replace (atomic on
POSIX), so a crash mid-write never corrupts the latest checkpoint.  Arrays
are saved UNSHARDED (gathered), so a restart may use a different mesh
shape — the loader reshards to whatever shardings the new mesh wants
(elastic scaling across pod/host counts).

For multi-host deployments the natural extension is one shard-file per
host + a barrier; on this single-process container the gathered form is
exact and keeps restarts bitwise-reproducible (tested in
tests/test_fault_tolerance.py by killing a trainer mid-run).
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten_with_names(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in leaves]
    vals = [v for _, v in leaves]
    return names, vals, jax.tree.structure(tree)


def save(path: str, step: int, state: Any, extra: Optional[dict] = None
         ) -> None:
    names, vals, _ = _flatten_with_names(state)
    os.makedirs(path, exist_ok=True)
    # bf16 has no stable npz codec across numpy versions: store widened to
    # f32 (exact) and narrow back on restore (bitwise for bf16 values).
    def enc(v):
        a = np.asarray(v)
        if a.dtype.name == "bfloat16":
            return a.astype(np.float32)
        return a
    arrs = {f"a{i}": enc(v) for i, v in enumerate(vals)}
    tmp_npz = os.path.join(path, f".tmp.{step}.npz")
    np.savez(tmp_npz, **arrs)
    manifest = {"step": int(step), "names": names,
                "extra": extra or {}}
    tmp_json = os.path.join(path, f".tmp.{step}.json")
    with open(tmp_json, "w") as f:
        json.dump(manifest, f)
    os.replace(tmp_npz, os.path.join(path, f"ckpt_{step:08d}.npz"))
    os.replace(tmp_json, os.path.join(path, f"ckpt_{step:08d}.json"))
    # update the LATEST pointer last (atomic)
    tmp_l = os.path.join(path, ".tmp.latest")
    with open(tmp_l, "w") as f:
        f.write(str(step))
    os.replace(tmp_l, os.path.join(path, "LATEST"))


def latest_step(path: str) -> Optional[int]:
    p = os.path.join(path, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def restore(path: str, state_like: Any, step: Optional[int] = None,
            shardings: Optional[Any] = None) -> Tuple[int, Any]:
    """Restore into the structure of ``state_like``; if ``shardings`` is
    given, device_put each leaf with it (elastic resharding)."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {path}")
    data = np.load(os.path.join(path, f"ckpt_{step:08d}.npz"))
    leaves, treedef = jax.tree_util.tree_flatten(state_like)
    vals = [data[f"a{i}"] for i in range(len(leaves))]

    def dec(v, l):
        if not hasattr(l, "dtype"):
            return v
        import ml_dtypes  # noqa: F401  (jax dependency, provides bf16)
        return np.asarray(v).astype(l.dtype)
    vals = [dec(v, l) for v, l in zip(vals, leaves)]
    if shardings is not None:
        sh_leaves = jax.tree.leaves(shardings,
                                    is_leaf=lambda x: x is None or hasattr(
                                        x, "spec"))
        vals = [jax.device_put(v, s) if s is not None else jax.device_put(v)
                for v, s in zip(vals, sh_leaves)]
    else:
        vals = [jax.device_put(v) for v in vals]
    return step, jax.tree_util.tree_unflatten(treedef, vals)
