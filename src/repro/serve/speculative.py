"""Speculative decoding rounds: draft-propose -> verify-all -> commit.

This module builds the compiled chunk a speculative ``SlotScheduler``
runs instead of the plain one-token-per-step scan.  One round, per slot:

  1. the DRAFT model (``models/draft.py`` — a truncated and/or
     count-sketch-compressed copy of the served weights) runs K+1 paged
     decode micro-steps through the slot's block table against its own
     shallow pool: the first K produce greedy proposals d_1..d_K, the
     last only writes the draft KV row for d_K so the draft pool stays in
     lockstep with whatever prefix ends up committed;
  2. the TARGET scores all K+1 positions in ONE multi-query decode
     (``transformer.verify_step``) — its logits at position pos+i are
     bitwise what a plain decode step would produce after committing the
     first i+1 tokens;
  3. the longest verified prefix commits, plus the target's correction /
     bonus token: the slot emits n+1 tokens where n is the count of
     leading proposals matching the target's greedy choice (clipped to
     the slot's spec_k, its remaining token budget, and forced to 0 for
     sampled slots, which instead draw their one token with their own
     key).  Rejection is positional rollback — the slot's position
     simply doesn't advance past the accepted prefix, and the rejected
     rows above it are rewritten by the next round before any causal
     mask can expose them.

Greedy speculative output is therefore token-for-token identical to
plain greedy decode — acceptance rate changes HOW FAST tokens commit,
never WHICH tokens — and slots with spec_k == 0 ride the same
compilation as one-verified-token-per-round participants, so mixed
spec / non-spec / sampled batches keep the engine's
one-compilation-per-lifetime contract.

Pump-step boundaries: each round clamps its emission to the slot's
remaining budget (``e = min(n + 1, remaining)``), so the committed
position and token count advance in lockstep — the scheduler's HOST
mirrors stay exact without reading device state, which is what lets the
async pump (serve/frontend.py) cancel, preempt or retire a speculating
slot at any chunk boundary: rejected overhang rows sit above the
committed position and are never observable by a successor occupant
(its table row is sentineled before the blocks free).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tf
from repro.serve import kv_sketch as kvs


def round_accounting(spec_k: int, emitted: int):
    """Host-side accounting for ONE step-row of a speculating slot:
    given the slot's proposal budget and the tokens that committed this
    round, return ``(rounds, proposed, accepted)``.

    The round emits the accepted draft prefix PLUS the target's
    correction/bonus token (step 3 above), so ``emitted - 1`` of the
    ``spec_k`` proposals survived verification.  A slot with no
    proposal budget — or a round that emitted nothing (budget already
    spent) — contributes no accounting.  Centralised here, next to the
    round semantics it mirrors, because both the scheduler's cumulative
    counters and the observer's windowed ``spec.*`` series consume it
    and must never disagree."""
    if spec_k <= 0 or emitted <= 0:
        return 0, 0, 0
    return 1, spec_k, emitted - 1


def build_spec_chunk(cfg: ModelConfig, draft_cfg: ModelConfig,
                     decode_chunk: int, spec_max: int, sample,
                     sketch=None, kernels=None):
    """Build the speculative decode chunk: ``decode_chunk`` rounds of
    propose/verify/commit over all slots, ONE compilation for the
    engine's lifetime.  ``sample`` is the scheduler's per-slot sampler
    (greedy when temp == 0, keyed top-k otherwise).  The returned
    ``spec_chunk_fn(params, draft_params, state)`` maps a DecodeState to
    (new_state, toks, emits) with toks/emits shaped
    (decode_chunk, B, spec_max + 1) — emitted tokens are the leading
    True-masked entries of each round's row, in order.

    ``sketch`` (sketched engines only) is the static fold geometry
    ``{"onehot", "coeffs", "fold_cap"}``; the returned fn then takes a
    4th argument ``fold_len`` (B,) and, at the chunk head, folds the
    aged exact-window rows of BOTH pools into the per-slot tail tables
    (speculation only ever folds COMMITTED rows: fold_base advances
    through positions the scheduler has already verified past).  Rounds
    then run two-span attention — draft propose and target verify both
    see exact window + sketched tail.

    ``kernels`` (static) routes draft micro-steps and the target verify
    through the flash-decode paged Pallas kernels
    (kernels/paged_attention.py); the kernel's verify rows are bitwise
    the kernel's single-token decode rows, so greedy spec identity holds
    on either implementation — but only when plain and speculative
    engines resolve the SAME choice, which the scheduler guarantees.
    """
    K = spec_max
    V = cfg.vocab_size

    def spec_chunk_fn(params, draft_params, state, fold_len=None):
        temp, top_k = state.temp, state.top_k
        spec_k = jnp.minimum(state.spec_k, K)
        tables = state.tables
        sk = None
        if sketch is not None:
            tail = kvs.fold_pool(state.cache["kv"], state.cache["tail"],
                                 tables, state.fold_base, fold_len,
                                 sketch["coeffs"], sketch["fold_cap"])
            dtail = kvs.fold_pool(state.cache["draft"]["kv"],
                                  state.cache["draft"]["tail"], tables,
                                  state.fold_base, fold_len,
                                  sketch["coeffs"], sketch["fold_cap"])
            fold_base = state.fold_base + fold_len
            sk = {"fold_base": fold_base, "onehot": sketch["onehot"]}

        def round_fn(carry, _):
            kv, dkv, cur, pos, remaining, keys = carry

            # -- draft: K proposals in K+1 micro-steps ----------------
            def dbody(c, i):
                dkv, tok = c
                lg, dkv = tf.decode_step(draft_params, dkv, tok,
                                         pos + i, draft_cfg,
                                         tables=tables, sketch=sk,
                                         kernels=kernels)
                nxt = jnp.argmax(lg[:, :V].astype(jnp.float32),
                                 axis=-1).astype(jnp.int32)
                return (dkv, nxt[:, None]), tok[:, 0]

            (dkv, _), fed = jax.lax.scan(dbody, (dkv, cur),
                                         jnp.arange(K + 1))
            vtok = jnp.swapaxes(fed, 0, 1)           # (B, K+1)

            # -- target: verify all K+1 positions at once -------------
            logits, kv = tf.verify_step(params, kv, vtok, pos, cfg,
                                        tables=tables, sketch=sk,
                                        kernels=kernels)
            lg = logits[..., :V].astype(jnp.float32)  # (B, K+1, V)
            greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)

            # -- accept the longest verified prefix -------------------
            drafts = vtok[:, 1:]                      # (B, K): d_1..d_K
            match = (drafts == greedy[:, :-1]).astype(jnp.int32)
            eligible = ((jnp.arange(K)[None, :] < spec_k[:, None])
                        & (temp[:, None] == 0.0)).astype(jnp.int32)
            n = jnp.sum(jnp.cumprod(match * eligible, axis=1),
                        axis=1).astype(jnp.int32)     # (B,)
            # sampled slots draw their one token with their own key
            keys, tok0 = sample(keys, lg[:, 0], temp, top_k)
            out = greedy.at[:, 0].set(tok0)           # (B, K+1)
            e = jnp.minimum(n + 1, remaining)         # emitted count
            emit = jnp.arange(K + 1)[None, :] < e[:, None]
            last = jnp.take_along_axis(
                out, jnp.maximum(e - 1, 0)[:, None], axis=1)[:, 0]
            cur = jnp.where(e > 0, last, cur[:, 0])[:, None]
            pos = pos + e
            remaining = remaining - e
            return (kv, dkv, cur, pos, remaining, keys), (out, emit)

        if sketch is not None:
            kv0 = {"kv": state.cache["kv"], "tail": tail}
            dkv0 = {"kv": state.cache["draft"]["kv"], "tail": dtail}
        else:
            kv0 = {"kv": state.cache["kv"]}
            dkv0 = state.cache["draft"]
        carry = (kv0, dkv0, state.cur, state.pos, state.remaining,
                 state.keys)
        (kv, dkv, cur, pos, remaining, keys), (toks, emits) = \
            jax.lax.scan(round_fn, carry, None, length=decode_chunk)
        if sketch is not None:
            new_cache = {"kv": kv["kv"], "tail": kv["tail"], "draft": dkv}
            new_state = state._replace(
                cache=new_cache, cur=cur, pos=pos, remaining=remaining,
                keys=keys, fold_base=fold_base)
        else:
            new_state = state._replace(
                cache={"kv": kv["kv"], "draft": dkv},
                cur=cur, pos=pos, remaining=remaining, keys=keys)
        return new_state, toks, emits    # toks/emits: (chunk, B, K+1)

    return spec_chunk_fn
