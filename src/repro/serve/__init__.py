"""Serving subsystem: continuous batching over a fixed slot cache.

Layering:
  prefix_cache.py — count-min (CSVec) gated prefix-KV admission under a
                    hard byte budget
  scheduler.py    — slot scheduler + the single compiled lax.scan decode
                    chunk with per-slot position/active/forced masks
  engine.py       — ServeEngine facade (batched generate API; synchronized
                    fallback for recurrent-state families)
"""
from repro.serve.engine import GenerationResult, ServeEngine, seed_cache
from repro.serve.prefix_cache import (PrefixCacheStats, SketchPrefixCache,
                                      prefix_key)
from repro.serve.scheduler import (KV_FAMILIES, Completion, DecodeState,
                                   Request, SlotScheduler)

__all__ = [
    "GenerationResult", "ServeEngine", "seed_cache",
    "PrefixCacheStats", "SketchPrefixCache", "prefix_key",
    "KV_FAMILIES", "Completion", "DecodeState", "Request", "SlotScheduler",
]
