"""Serving subsystem: continuous batching over fixed per-slot state.

Layering:
  prefix_cache.py — count-min (CSVec) gated prefix admission; entries are
                    refcounted paged-pool block ids under a hard byte
                    budget (zero-copy prefix sharing)
  scheduler.py    — slot scheduler + BlockAllocator (paged-KV free list /
                    refcounts / copy-on-write forks) + the single
                    compiled lax.scan decode chunk with per-slot
                    position/active/sampling/spec_k state and block
                    tables; chunked prefill for attention families,
                    slot-inserted recurrent state for ssm/hybrid
  speculative.py  — the speculative decode chunk (serve.spec_k > 0):
                    draft-propose (models/draft.py derived proposer) /
                    verify-all (transformer.verify_step) / commit-
                    accepted rounds, greedy-identical to plain decode
  engine.py       — ServeEngine facade (batched generate API with
                    per-request temperature/top-k/spec_k)
"""
from repro.serve.engine import GenerationResult, ServeEngine
from repro.serve.prefix_cache import (PrefixCacheStats, SketchPrefixCache,
                                      prefix_key)
from repro.serve.scheduler import (KV_FAMILIES, RECURRENT_FAMILIES,
                                   BlockAllocator, Completion, DecodeState,
                                   Request, SlotScheduler)

__all__ = [
    "GenerationResult", "ServeEngine",
    "PrefixCacheStats", "SketchPrefixCache", "prefix_key",
    "KV_FAMILIES", "RECURRENT_FAMILIES", "BlockAllocator", "Completion",
    "DecodeState", "Request", "SlotScheduler",
]
