"""Serving subsystem: continuous batching over fixed per-slot state.

Layering:
  prefix_cache.py — count-min (CSVec) gated prefix admission; entries are
                    refcounted paged-pool block ids under a hard byte
                    budget (zero-copy prefix sharing)
  kv_sketch.py    — sketched long-context KV: exact recent window +
                    per-slot FCS tail tables, folded inside the chunk
  scheduler.py    — slot scheduler + BlockAllocator (paged-KV free list /
                    refcounts / copy-on-write forks) + the single
                    compiled lax.scan decode chunk with per-slot
                    position/active/sampling/spec_k state and block
                    tables; chunked prefill for attention families,
                    slot-inserted recurrent state for ssm/hybrid.  The
                    host loop is phase-split (admit_pending / dispatch /
                    collect, cancel / preempt / expire_deadlines at pump
                    boundaries) with host mirrors of per-slot state, so
                    admission overlaps the in-flight device chunk
  speculative.py  — the speculative decode chunk (serve.spec_k > 0):
                    draft-propose (models/draft.py derived proposer) /
                    verify-all (transformer.verify_step) / commit-
                    accepted rounds, greedy-identical to plain decode
  frontend.py     — AsyncServeEngine: the always-on asyncio pump over
                    the phase API (submit -> StreamHandle, per-token
                    streaming, cancellation, deadlines/priorities with
                    preemption, bounded-queue backpressure)
  engine.py       — ServeEngine facade (batched generate API, now a
                    thin wrapper over the async front-end) + the
                    unified EngineStats snapshot

Observability: every layer above holds an optional ``obs`` attribute (a
``repro.obs.ServeObserver`` or None) and guards each hook site with one
attribute check — request lifecycle spans, pump-phase timings,
fold/spec/prefix events and the opt-in sketch-fidelity probe stream out
with zero added device syncs.  See ``repro.obs``.
"""
from repro.serve.engine import GenerationResult, ServeEngine
from repro.serve.frontend import AsyncServeEngine, StreamHandle
from repro.serve.prefix_cache import (PrefixCacheStats, SketchPrefixCache,
                                      prefix_key)
from repro.serve.scheduler import (KV_FAMILIES, RECURRENT_FAMILIES,
                                   BlockAllocator, Completion, DecodeState,
                                   EngineStats, Request, SlotScheduler)

__all__ = [
    "GenerationResult", "ServeEngine",
    "AsyncServeEngine", "StreamHandle",
    "PrefixCacheStats", "SketchPrefixCache", "prefix_key",
    "KV_FAMILIES", "RECURRENT_FAMILIES", "BlockAllocator", "Completion",
    "DecodeState", "EngineStats", "Request", "SlotScheduler",
]
