"""Serving subsystem: continuous batching over fixed per-slot state.

Layering:
  prefix_cache.py — count-min (CSVec) gated prefix admission; entries are
                    refcounted paged-pool block ids under a hard byte
                    budget (zero-copy prefix sharing)
  scheduler.py    — slot scheduler + BlockAllocator (paged-KV free list /
                    refcounts) + the single compiled lax.scan decode
                    chunk with per-slot position/active/sampling state
                    and block tables; chunked prefill for attention
                    families, slot-inserted recurrent state for ssm/hybrid
  engine.py       — ServeEngine facade (batched generate API with
                    per-request temperature/top-k)
"""
from repro.serve.engine import GenerationResult, ServeEngine
from repro.serve.prefix_cache import (PrefixCacheStats, SketchPrefixCache,
                                      prefix_key)
from repro.serve.scheduler import (KV_FAMILIES, RECURRENT_FAMILIES,
                                   BlockAllocator, Completion, DecodeState,
                                   Request, SlotScheduler)

__all__ = [
    "GenerationResult", "ServeEngine",
    "PrefixCacheStats", "SketchPrefixCache", "prefix_key",
    "KV_FAMILIES", "RECURRENT_FAMILIES", "BlockAllocator", "Completion",
    "DecodeState", "Request", "SlotScheduler",
]
