"""Sketched long-context KV: per-slot, per-layer FCS tail tables.

The paged pool (serve/scheduler.py) bounds a slot's KV by its RESERVED
blocks — fine for mixed-length streams, but a long document still needs
ceil(context / block_size) live blocks.  This module decouples context
length from pool blocks the same way sketch/optimizer.py decouples
optimizer state from parameter count: when a block ages past the recent
window (``cfg.serve.kv_sketch_window``), its key and value rows are
count-sketched ALONG THE SEQUENCE AXIS into a per-slot, per-layer
(rows, cols, K, hd) tail table and the block returns to the free list.
Sketches are linear, so folding is a batched signed bucket-accumulate
(the CS half of the paper's FCS, hashes from sketch/hashing.py evaluated
on the fly), and it rides inside the compiled decode chunk — the
scheduler's one-compilation contract is untouched.

Decode attention becomes TWO-SPAN:

  exact span   — paged attention over [fold_base, pos], bit-identical
                 ops to the pre-sketch path (the regression anchor: when
                 nothing has folded the engine selects this output
                 verbatim, so window >= context runs are bitwise equal
                 to a sketch-free engine's);
  sketch tail  — scores against folded positions j < fold_base are
                 estimated per hash row as q . tail_k[r, h_r(j)] * s_r(j)
                 (one MXU contraction against a precomputed signed
                 position-one-hot), median-combined over rows; the
                 softmax weight vector w over the tail is then itself
                 count-sketched per row (CS is linear: sum_j w_j v_j =
                 <CS_r(w), tail_v[r]> exactly, up to collisions) and the
                 weighted value sum is median-combined the same way.

The two spans merge with online-softmax (m, l, acc) statistics, exactly
like kernels-level flash attention — an empty tail contributes weight
zero, so the merge is total.

Everything here is dependency-light (configs + sketch.hashing only) so
models/layers.py can import it; kernels/kv_sketch.py carries the Pallas
fold+query kernels with kernels/ref.py oracles delegating to this math.

Fold points are PUMP-STEP BOUNDARIES: the scheduler plans each chunk's
fold lengths from its host position mirrors (``_plan_folds``, at
dispatch), the chunk folds at its head, and the freed blocks leave the
slot's table at dispatch time too (``_finish_folds`` — the sentinel
writes enqueue after the chunk in device-stream order).  Only COMMITTED
rows ever fold, so the async pump (serve/frontend.py) can cancel or
preempt a sketched slot at any boundary: the tail tables are per-slot
state, zeroed lazily at the next admission, and the fold frontier
resets with the slot.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ServeConfig
from repro.sketch.hashing import cached_coeffs, row_buckets_signs

# tail-table hash seeds derive from the serve seed but never collide with
# the prefix cache's count-min seed (same hashing family)
_SEED_SALT = 0x4B56AD  # "KV"-flavoured salt


def tail_seed(sv: ServeConfig) -> int:
    return (int(sv.seed) ^ _SEED_SALT) & 0x7FFFFFFF


def tail_coeffs(sv: ServeConfig) -> jax.Array:
    """(rows, 4) uint32 hash coefficients for the tail tables."""
    return cached_coeffs(tail_seed(sv), sv.kv_sketch_rows)


def tail_cols(max_seq: int, ratio: int) -> int:
    """Tail-table columns: ~max_seq / ratio, rounded UP to a multiple of
    16 (lane alignment + 16-way model-axis shardability), at least 16."""
    c = -(-max_seq // max(1, ratio))
    return max(16, -(-c // 16) * 16)


def pos_domain(max_seq: int, block_size: int) -> int:
    """Hashed position domain T: every foldable absolute position lives
    in [0, T) — whole blocks only, so round max_seq up to blocks."""
    return -(-max_seq // block_size) * block_size


def pos_onehot(coeffs: jax.Array, T: int, cols: int) -> jax.Array:
    """(rows, T, cols) signed position one-hot: onehot[z, j, c] =
    s_z(j) * [h_z(j) == c].  Shared by fold (accumulate = x @ onehot) and
    query (estimate gather = table-products @ onehot^T); both sides use
    the same in-graph hashes, so fold and query can never disagree."""
    idx = jnp.arange(T, dtype=jnp.int32)
    bk, sg = row_buckets_signs(coeffs, idx, cols, signed=True)   # (Z, T)
    cols_iota = jnp.arange(cols, dtype=jnp.int32)
    return jnp.where(cols_iota[None, None, :] == bk[:, :, None],
                     sg[:, :, None], 0.0).astype(jnp.float32)


def init_tail(cfg: ModelConfig, batch: int, rows: int, cols: int
              ) -> Dict[str, jax.Array]:
    """Per-slot, per-layer tail tables: {"k","v"} of
    (L, B, rows, cols, K, hd) f32 zeros.  f32 because folds accumulate
    hundreds of signed bf16 rows per bucket."""
    hd = cfg.resolved_head_dim
    shape = (cfg.num_layers, batch, rows, cols, cfg.num_kv_heads, hd)
    # two distinct buffers — donation of a state pytree holding the SAME
    # zeros array twice is an XLA error ("donate the same buffer twice")
    return {"k": jnp.zeros(shape, jnp.float32),
            "v": jnp.zeros(shape, jnp.float32) + 0.0}


def tail_state_bytes(tail: Any) -> int:
    return sum(int(a.size) * int(a.dtype.itemsize)
               for a in jax.tree.leaves(tail))


# ---------------------------------------------------------------------------
# Fold: pool blocks -> tail tables (linear accumulate, in-graph)
# ---------------------------------------------------------------------------


def fold_pool(pool: Dict[str, jax.Array], tail: Dict[str, jax.Array],
              tables: jax.Array, fold_from: jax.Array, fold_len: jax.Array,
              coeffs: jax.Array, fold_cap: int) -> Dict[str, jax.Array]:
    """Fold each slot's next ``fold_len[b]`` aged KV rows into its tail.

    pool: {"k","v"} (L, NB, bs, K, hd) — the paged block pool; the rows
    being folded are still table-mapped (the host frees their blocks only
    after this runs).  tail: {"k","v"} (L, B, Z, C, K, hd).  tables:
    (B, blocks_per_slot) int32.  fold_from: (B,) first absolute position
    to fold (the slot's current fold_base; block-aligned).  fold_len:
    (B,) rows to fold, a multiple of the block size, <= ``fold_cap``
    (static).  All arrays traced — one compilation covers every fold.
    """
    NB, bs = pool["k"].shape[1], pool["k"].shape[2]
    B = tables.shape[0]
    F = int(fold_cap)
    if F == 0:
        return tail
    p = fold_from[:, None] + jnp.arange(F, dtype=jnp.int32)[None, :]  # (B,F)
    valid = (jnp.arange(F, dtype=jnp.int32)[None, :]
             < fold_len[:, None]).astype(jnp.float32)                 # (B,F)
    blk = jnp.clip(p // bs, 0, tables.shape[1] - 1)
    phys = jnp.take_along_axis(tables, blk, axis=1)                   # (B,F)
    phys = jnp.clip(phys, 0, NB - 1)      # invalid rows are masked by valid
    off = p % bs
    Z = tail["k"].shape[2]
    C = tail["k"].shape[3]
    bk, sg = row_buckets_signs(coeffs, p.reshape(-1), C, signed=True)
    bk = bk.reshape(Z, B, F)
    sg = sg.reshape(Z, B, F) * valid[None, :, :]
    cols_iota = jnp.arange(C, dtype=jnp.int32)
    onehot = jnp.where(cols_iota[None, None, None, :] == bk[..., None],
                       sg[..., None], 0.0)                       # (Z,B,F,C)

    def one(pool_a, tail_a):
        rows = pool_a[:, phys, off].astype(jnp.float32)          # (L,B,F,K,hd)
        return tail_a + jnp.einsum("zbfc,lbfkh->lbzckh", onehot, rows)

    return {"k": one(pool["k"], tail["k"]),
            "v": one(pool["v"], tail["v"])}


# ---------------------------------------------------------------------------
# Query: online-softmax statistics of the sketched tail span
# ---------------------------------------------------------------------------


def tail_attend(q: jax.Array, tail_k: jax.Array, tail_v: jax.Array,
                onehot: jax.Array, fold_base: jax.Array, scale: float
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Approximate attention statistics over the folded span [0, fold_base).

    q: (B, Sq, K, R, hd) f32 queries; tail_k/tail_v: (B, Z, C, K, hd) f32
    (one layer's tables); onehot: (Z, T, C) from ``pos_onehot``;
    fold_base: (B,) int32.  Every query position is >= fold_base (folded
    rows are strictly older than the exact window), so the whole tail is
    causally visible — no per-query mask, only the live-span mask.

    Returns flash-style (m, l, acc): (B, K, R, Sq), same, and
    (B, K, R, Sq, hd) — merge-ready against the exact span's statistics.
    An empty tail (fold_base == 0) yields m = -1e30, l = 0, acc = 0, so
    the merge degenerates to the exact span exactly.
    """
    T = onehot.shape[1]
    qf = q.astype(jnp.float32)
    tk = tail_k.astype(jnp.float32)
    # per-row bucket products, then gather each position's bucket estimate
    qa = jnp.einsum("bqkrh,bzckh->bzkrqc", qf, tk)
    est = jnp.einsum("bzkrqc,ztc->bzkrqt", qa, onehot)     # (B,Z,K,R,Sq,T)
    s = jnp.median(est, axis=1) * scale                    # (B,K,R,Sq,T)
    live = (jnp.arange(T, dtype=jnp.int32)[None, :]
            < fold_base[:, None])                          # (B,T)
    lm = live[:, None, None, None, :]
    s = jnp.where(lm, s, -1e30)
    m = jnp.max(s, axis=-1)                                # (B,K,R,Sq)
    w = jnp.exp(s - m[..., None])
    # exp(-1e30 - (-1e30)) == 1 when the span is empty: kill dead weights
    w = jnp.where(lm, w, 0.0)
    l = jnp.sum(w, axis=-1)
    # CS is linear: sum_j w_j * v_j  ~=  < CS_z(w), tail_v[z] > per row
    cw = jnp.einsum("bkrqt,ztc->bzkrqc", w, onehot)
    acc = jnp.median(jnp.einsum("bzkrqc,bzckh->bzkrqh", cw,
                                tail_v.astype(jnp.float32)), axis=1)
    return m, l, acc


def merge_spans(m_e: jax.Array, l_e: jax.Array, acc_e: jax.Array,
                m_t: jax.Array, l_t: jax.Array, acc_t: jax.Array
                ) -> jax.Array:
    """Online-softmax merge of exact-window and sketch-tail statistics.
    All f32; shapes (B,K,R,Sq) / (B,K,R,Sq,hd).  Returns (B,K,R,Sq,hd).
    The exact span is never empty (a live query always sees its own
    position), so the denominator is positive."""
    m = jnp.maximum(m_e, m_t)
    a_e = jnp.exp(m_e - m)
    a_t = jnp.exp(m_t - m)
    num = acc_e * a_e[..., None] + acc_t * a_t[..., None]
    den = l_e * a_e + l_t * a_t
    return num / jnp.maximum(den, 1e-30)[..., None]


def exact_span_stats(s: jax.Array, v: jax.Array, live: jax.Array
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """f32 online-softmax statistics of an exact masked score tensor.
    s: (B, K, R, Sq, Sk) with dead positions already at -1e30; ``live``
    is the bool mask that produced them (exp(-1e30 - (-1e30)) == 1, so
    dead weights must be re-zeroed after the exp); v: (B, Sk, K, hd).
    Returns (m, l, acc) matching tail_attend."""
    m = jnp.max(s, axis=-1)
    p = jnp.where(live, jnp.exp(s - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkrqs,bskh->bkrqh", p, v.astype(jnp.float32))
    return m, l, acc


# ---------------------------------------------------------------------------
# Dense oracle (tests / benchmarks)
# ---------------------------------------------------------------------------


def dense_tail_stats(q: jax.Array, k: jax.Array, v: jax.Array,
                     fold_base: jax.Array, scale: float):
    """Exact (m, l, acc) over the folded span — what tail_attend
    approximates.  k/v: (B, T, K, hd) the TRUE rows at absolute
    positions [0, T)."""
    qf = q.astype(jnp.float32)
    s = jnp.einsum("bqkrh,bskh->bkrqs", qf,
                   k.astype(jnp.float32)) * scale
    T = k.shape[1]
    live = (jnp.arange(T)[None, :] < fold_base[:, None]
            )[:, None, None, None, :]
    s = jnp.where(live, s, -1e30)
    m = jnp.max(s, axis=-1)
    w = jnp.where(live, jnp.exp(s - m[..., None]), 0.0)
    l = jnp.sum(w, axis=-1)
    acc = jnp.einsum("bkrqs,bskh->bkrqh", w, v.astype(jnp.float32))
    return m, l, acc


# ---------------------------------------------------------------------------
# Fidelity probe (observability): per-row estimate spread
# ---------------------------------------------------------------------------


def tail_row_spread(tail: Dict[str, jax.Array]) -> jax.Array:
    """Per-slot relative spread of the Z independent hash-row tail
    estimates — the live collision-variance proxy behind the ROADMAP's
    error-adaptive folding ("monitor the tail's median-estimate
    spread").

    Each hash row z holds an independent count-sketch of the SAME
    folded rows, so its total energy e_z = sum over (L, C, K, hd) of
    tail_k^2 + tail_v^2 equals sum_j ||k_j||^2 + ||v_j||^2 exactly when
    no two folded positions collide in row z, and picks up
    2 * s_i s_j <x_i, x_j> cross terms when they do.  Rows that agree
    mean the median-of-rows estimates the engine decodes with are
    trustworthy; rows that diverge mean collisions are corrupting the
    tail and the slot is a candidate for a wider exact window or a
    re-fold.

    tail: {"k","v"} of (L, B, Z, C, K, hd).  Returns (B,) f32:
    (max_z e - min_z e) / median_z e, 0 for an empty (all-zero) tail.

    Observability contract: this is HOST-OPT-IN telemetry — the
    scheduler calls it (jitted) only at its configured probe cadence
    and only at the ``collect()`` boundary where the round's sync
    already happened; it is never traced into the compiled decode
    chunk.
    """
    e = (jnp.sum(jnp.square(tail["k"]), axis=(0, 3, 4, 5)) +
         jnp.sum(jnp.square(tail["v"]), axis=(0, 3, 4, 5)))   # (B, Z)
    med = jnp.median(e, axis=1)
    spread = jnp.max(e, axis=1) - jnp.min(e, axis=1)
    return jnp.where(med > 0.0, spread / jnp.maximum(med, 1e-30), 0.0)


def fold_rows(k: jax.Array, v: jax.Array, positions: jax.Array,
              coeffs: jax.Array, cols: int):
    """Reference fold of explicit rows (no pool/tables): k/v
    (B, n, K, hd) at absolute ``positions`` (n,) -> tail {"k","v"}
    (B, Z, cols, K, hd).  Shares row_buckets_signs with fold_pool, so the
    two folds agree bitwise for the same rows."""
    bk, sg = row_buckets_signs(coeffs, positions.astype(jnp.int32), cols,
                               signed=True)                       # (Z, n)
    cols_iota = jnp.arange(cols, dtype=jnp.int32)
    onehot = jnp.where(cols_iota[None, None, :] == bk[:, :, None],
                       sg[:, :, None], 0.0)                       # (Z,n,C)
    fold = lambda x: jnp.einsum("znc,bnkh->bzckh", onehot,
                                x.astype(jnp.float32))
    return {"k": fold(k), "v": fold(v)}
