"""Serving engine facade over the continuous-batching slot scheduler.

``ServeEngine.generate`` keeps the classic batched-generation API (a (B, S)
prompt matrix in, a (B, max_new) token matrix out) but is implemented on
top of ``serve.scheduler.SlotScheduler`` for EVERY family: requests are
admitted into fixed-geometry slot state (a KV cache for attention
families, stacked recurrent state for ssm / hybrid), decode is ONE
compiled ``lax.scan`` chunk for the engine's lifetime, attention-family
prompts are prefilled in bucket-sized chunks straight into the slot cache,
and repeated prompts are served through the count-min gated prefix cache.
The old synchronized recurrent fallback (prefill-once + whole-batch
lockstep decode) is gone — ssm / hybrid requests ride the same scheduler,
with their per-layer recurrent states slot-inserted at admission.

Sampling is per-request: ``temperature`` / ``top_k`` may be scalars (one
setting for the whole batch) or length-B sequences, and they become
per-slot engine state — mixed greedy / sampled streams share the single
compiled decode chunk.

Speculative decoding rides the same facade: with ``cfg.serve.spec_k > 0``
the scheduler derives a draft model (``models/draft.py``) and decode
rounds become propose-K / verify-all / commit-accepted — ``generate``'s
``spec_k`` argument (scalar or per-request vector) opts individual
requests up or down, and greedy output stays bitwise identical to plain
decode either way.

``generate`` itself is a THIN COMPATIBILITY WRAPPER over the async
front-end (``serve.frontend.AsyncServeEngine``): each batch row becomes
one streamed submission against the cached scheduler's pump, drained to
completion inside an ``asyncio.run``.  Greedy batch output is bitwise
identical to the streamed output — there is exactly one serving path.
``ServeEngine.stats()`` returns the unified ``EngineStats`` snapshot
(queue depth, pool occupancy, prefix-cache hit rate, fold counts,
speculative acceptance) merged across the engine's schedulers.
"""
from __future__ import annotations

import asyncio
import dataclasses
from dataclasses import dataclass
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.draft import make_draft
from repro.serve.frontend import AsyncServeEngine
from repro.serve.scheduler import EngineStats, SlotScheduler

Per = Union[float, int, Sequence, jax.Array, np.ndarray]


@dataclass
class GenerationResult:
    tokens: jax.Array          # (B, max_new)
    prompt_len: int


def _per_request(val: Per, B: int, name: str) -> np.ndarray:
    """Broadcast a scalar or validate a length-B per-request vector."""
    arr = np.asarray(val)
    if arr.ndim == 0:
        return np.full((B,), arr.item())
    assert arr.shape == (B,), f"{name} must be scalar or ({B},), got {arr.shape}"
    return arr


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, max_seq: int = 512,
                 max_batch: Optional[int] = None, obs=None):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.max_batch = max_batch
        self._schedulers = {}        # max_batch -> SlotScheduler
        self._frontends = {}         # max_batch -> AsyncServeEngine
        self._draft = None           # derived once, shared by schedulers
        self._rid = 0
        # one shared repro.obs.ServeObserver (or None) across every
        # scheduler/front-end this engine creates: merged EngineStats
        # (kind-tagged: counters sum, gauges disjoint-sum, peaks max)
        # and the observer's windowed series describe the same engine
        self._obs = obs

    def set_observer(self, obs) -> None:
        """Attach an observer to the engine: applies to every live
        scheduler now and to schedulers created later."""
        self._obs = obs
        for s in self._schedulers.values():
            s.set_observer(obs)
        for f in self._frontends.values():
            f.obs = obs

    # ------------------------------------------------------------------

    def _scheduler(self, batch: int) -> SlotScheduler:
        """One scheduler per slot count: the decode chunk is specialized
        on the slot geometry only (sampling params are per-slot state), so
        reusing it across generate() calls keeps the compile count at one
        and lets the prefix cache warm up across calls.  If ``self.params``
        has been swapped (e.g. a checkpoint was loaded), every cached
        scheduler is dropped — its prefix cache holds KV blocks computed
        from the old weights, so serving them would silently mix models."""
        if self._schedulers and next(
                iter(self._schedulers.values())).params is not self.params:
            self._schedulers.clear()
            self._frontends.clear()  # they wrap the dropped schedulers
            self._draft = None       # derived from the old weights
        kb = self.max_batch or batch
        if kb not in self._schedulers:
            serve = dataclasses.replace(
                self.cfg.serve, max_batch=kb, max_seq=self.max_seq)
            if serve.spec_k > 0 and self._draft is None:
                # derive the draft ONCE per weights: compress_params is
                # a real derivation pass, and the draft doesn't depend
                # on the slot geometry — every scheduler shares it
                self._draft = make_draft(self.params, self.cfg, serve)
            self._schedulers[kb] = SlotScheduler(
                self.cfg, self.params, serve=serve, draft=self._draft,
                obs=self._obs)
        return self._schedulers[kb]

    def _frontend(self, batch: int) -> AsyncServeEngine:
        """The async front-end wrapping the cached scheduler for this
        slot count — ``generate`` is a thin compatibility facade over
        it, so batch and streaming callers share one warmed-up engine
        (one decode compilation, one prefix cache)."""
        sched = self._scheduler(batch)    # may clear self._frontends
        kb = self.max_batch or batch
        if kb not in self._frontends:
            self._frontends[kb] = AsyncServeEngine(scheduler=sched)
        return self._frontends[kb]

    def generate(self, tokens: jax.Array, max_new: int = 32,
                 temperature: Per = 0.0, top_k: Per = 0,
                 key: Optional[jax.Array] = None,
                 spec_k: Optional[Per] = None,
                 kv_sketch: Optional[Per] = None) -> GenerationResult:
        """tokens: (B, S) prompt ids.  ``temperature`` / ``top_k`` /
        ``spec_k`` may be scalars or per-request length-B vectors; a
        request is greedy when its temperature is 0.  When sampling and
        no key is given, per-slot keys derive from cfg.serve.seed and the
        request id — sampling without a key is a valid request, not a
        crash.  ``spec_k`` (speculative tokens per verify round) defaults
        to ``cfg.serve.spec_k`` and is clamped to it: speculation only
        runs when the engine was built with a draft (spec_k > 0 in the
        serve config), but individual requests may opt down to plain
        decode with spec_k=0.  ``kv_sketch`` (scalar or per-request
        bools) opts requests OUT of long-context KV sketching on engines
        built with ``cfg.serve.kv_sketch_window > 0`` — a False keeps
        that request's whole context exact."""
        B, S = tokens.shape
        assert S + max_new <= self.max_seq
        front = self._frontend(B)
        temps = _per_request(temperature, B, "temperature")
        ks = _per_request(top_k, B, "top_k")
        sks = (None if spec_k is None
               else _per_request(spec_k, B, "spec_k"))
        kss = (None if kv_sketch is None
               else _per_request(kv_sketch, B, "kv_sketch"))
        prompts = np.asarray(tokens, np.int32)
        rids = list(range(self._rid, self._rid + B))
        self._rid += B

        async def go():
            handles = []
            for b in range(B):
                # explicit key → per-slot keys fold in the BATCH ROW,
                # not the engine-global rid: calling generate twice with
                # the same key reproduces the same sampled tokens, and
                # the scheduler's default key stream is left untouched
                # for key=None calls
                rk = (jax.random.fold_in(key, b)
                      if key is not None else None)
                handles.append(await front.submit(
                    prompts[b], max_new=max_new,
                    temperature=float(temps[b]), top_k=int(ks[b]),
                    key=rk,
                    spec_k=(None if sks is None else int(sks[b])),
                    kv_sketch=(None if kss is None else bool(kss[b])),
                    deadline_s=0,         # batch callers never expire
                    rid=rids[b]))
            return [await h.result() for h in handles]

        done = asyncio.run(go())
        out = np.stack([c.tokens for c in done])
        return GenerationResult(tokens=jnp.asarray(out), prompt_len=S)

    # ------------------------------------------------------------------

    @property
    def decode_compilations(self) -> int:
        """Total decode-step compilations across all live schedulers."""
        return sum(s.decode_compilations
                   for s in self._schedulers.values())

    def stats(self) -> EngineStats:
        """Unified observability snapshot across every live scheduler:
        queue depth, slot occupancy, pool occupancy/peak, prefix-cache
        hit rate, fold counts, speculative acceptance.  Replaces the
        old per-scheduler ``prefix_cache_stats`` dict."""
        return EngineStats.merge(
            [s.stats() for s in self._schedulers.values()])
