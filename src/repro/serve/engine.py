"""Batched serving engine: prefill + incremental decode over a KV cache.

The decode step is the jitted ``serve_step`` the dry-run lowers; this engine
adds request batching, greedy/temperature sampling, and cache management on
top.  Long-context decode relies on the split-KV sharding rules
(launch/shardings.decode_rules) when run under a mesh.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models import transformer as tf


@dataclass
class GenerationResult:
    tokens: jax.Array          # (B, max_new)
    prompt_len: int


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, max_seq: int = 512):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self._decode = jax.jit(
            functools.partial(tf.decode_step, cfg=cfg), donate_argnums=(1,))
        self._prefill = jax.jit(functools.partial(tf.prefill, cfg=cfg))

    def _grow_cache(self, cache, cur_len: int):
        """Pad attention caches from prompt length to max_seq slots."""
        pad = self.max_seq - cur_len
        if pad <= 0:
            return cache

        def grow(path, leaf):
            name = str(path[-1])
            if leaf.ndim == 5 and leaf.shape[2] == cur_len:  # (L,B,S,K,hd)
                return jnp.pad(leaf, ((0, 0), (0, 0), (0, pad),
                                      (0, 0), (0, 0)))
            return leaf
        return jax.tree_util.tree_map_with_path(grow, cache)

    def generate(self, tokens: jax.Array, max_new: int = 32,
                 temperature: float = 0.0,
                 key: Optional[jax.Array] = None) -> GenerationResult:
        """tokens: (B, S) prompt ids.  Greedy when temperature == 0."""
        B, S = tokens.shape
        assert S + max_new <= self.max_seq
        logits, cache = self._prefill(self.params, {"tokens": tokens})
        cache = self._grow_cache(cache, S)
        out = []
        cur = None
        for t in range(max_new):
            if t == 0:
                lg = logits
            else:
                lg, cache = self._decode(self.params, cache, cur,
                                         jnp.int32(S + t - 1))
            lg = lg[:, :self.cfg.vocab_size]
            if temperature > 0.0:
                key, k = jax.random.split(key)
                nxt = jax.random.categorical(k, lg / temperature, axis=-1)
            else:
                nxt = jnp.argmax(lg, axis=-1)
            cur = nxt[:, None].astype(jnp.int32)
            out.append(nxt)
        return GenerationResult(tokens=jnp.stack(out, axis=1), prompt_len=S)
