"""Serving engine facade over the continuous-batching slot scheduler.

``ServeEngine.generate`` keeps the classic batched-generation API (a (B, S)
prompt matrix in, a (B, max_new) token matrix out) but is now implemented on
top of ``serve.scheduler.SlotScheduler``: requests are admitted into a
fixed-geometry slot cache, decode is ONE compiled ``lax.scan`` chunk for the
engine's lifetime, and repeated prompts are served through the count-min
gated prefix cache.  The old per-request cache-regrow hack
(``_grow_cache``) is gone — the cache is preallocated at
(L, max_batch, max_seq, K, hd) and never reshaped.

Recurrent-state families (ssm / hybrid) have no per-position KV rows to
slot-schedule, so they use a synchronized decode loop: prefill once, seed a
full-size preallocated cache (``seed_cache`` — equal-shape state leaves are
taken wholesale, seq-extent leaves are inserted at position 0), then step
the whole batch at a shared scalar position.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tf
from repro.serve.scheduler import KV_FAMILIES, Request, SlotScheduler


@dataclass
class GenerationResult:
    tokens: jax.Array          # (B, max_new)
    prompt_len: int


def seed_cache(full, pre):
    """Copy a prefill cache into a preallocated max-length cache: leaves
    with matching shapes (recurrent states) are taken from the prefill
    wholesale; seq-extent leaves (e.g. hybrid shared_kv (G, B, S, K, hd))
    are written at offset 0, with the tail left as zeros — those rows are
    always rewritten by decode before any query can attend to them."""
    def one(f, p):
        if f.shape == p.shape:
            return p.astype(f.dtype)
        return jax.lax.dynamic_update_slice(
            f, p.astype(f.dtype), (0,) * f.ndim)
    return jax.tree.map(one, full, pre)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, max_seq: int = 512,
                 max_batch: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.max_batch = max_batch
        self._schedulers = {}        # (B, temperature) -> SlotScheduler
        self._rid = 0
        if cfg.family not in KV_FAMILIES:
            self._decode = jax.jit(
                functools.partial(tf.decode_step, cfg=cfg),
                donate_argnums=(1,))
            self._prefill = jax.jit(functools.partial(tf.prefill, cfg=cfg))
            self._seed_cache = jax.jit(seed_cache, donate_argnums=(0,))

    # ------------------------------------------------------------------

    def _scheduler(self, batch: int, temperature: float) -> SlotScheduler:
        """One scheduler per (max_batch, temperature): the decode chunk is
        specialized on both, and reusing it across generate() calls is what
        keeps the compile count at one (and lets the prefix cache warm up
        across calls).  If ``self.params`` has been swapped (e.g. a
        checkpoint was loaded), every cached scheduler is dropped — its
        prefix cache holds KV blocks computed from the old weights, so
        serving them would silently mix models."""
        if self._schedulers and next(
                iter(self._schedulers.values())).params is not self.params:
            self._schedulers.clear()
        kb = self.max_batch or batch
        sk = (kb, float(temperature))
        if sk not in self._schedulers:
            serve = dataclasses.replace(
                self.cfg.serve, max_batch=kb, max_seq=self.max_seq)
            self._schedulers[sk] = SlotScheduler(
                self.cfg, self.params, serve=serve, temperature=temperature)
        return self._schedulers[sk]

    def generate(self, tokens: jax.Array, max_new: int = 32,
                 temperature: float = 0.0,
                 key: Optional[jax.Array] = None) -> GenerationResult:
        """tokens: (B, S) prompt ids.  Greedy when temperature == 0.
        When sampling (temperature > 0) and no key is given, a PRNGKey
        seeded from cfg.serve.seed is used — sampling without a key is a
        valid request, not a crash."""
        B, S = tokens.shape
        assert S + max_new <= self.max_seq
        if self.cfg.family in KV_FAMILIES:
            return self._generate_slots(tokens, max_new, temperature, key)
        return self._generate_sync(tokens, max_new, temperature, key)

    # -- continuous-batching path (attention families) -------------------

    def _generate_slots(self, tokens, max_new, temperature, key):
        B, S = tokens.shape
        sched = self._scheduler(B, temperature)
        if key is not None:
            sched.reseed(key)
        prompts = np.asarray(tokens, np.int32)
        reqs = []
        for b in range(B):
            reqs.append(Request(rid=self._rid, tokens=prompts[b],
                                max_new=max_new))
            self._rid += 1
        done = {c.rid: c for c in sched.run(reqs)}
        out = np.stack([done[r.rid].tokens for r in reqs])
        return GenerationResult(tokens=jnp.asarray(out), prompt_len=S)

    # -- synchronized fallback (recurrent-state families) -----------------

    def _generate_sync(self, tokens, max_new, temperature, key):
        B, S = tokens.shape
        if temperature > 0.0 and key is None:
            key = jax.random.PRNGKey(self.cfg.serve.seed)
        logits, pre = self._prefill(self.params, {"tokens": tokens})
        cache = self._seed_cache(tf.init_cache(self.cfg, B, self.max_seq),
                                 pre)
        out = []
        cur = None
        for t in range(max_new):
            if t == 0:
                lg = logits
            else:
                lg, cache = self._decode(self.params, cache, cur,
                                         jnp.int32(S + t - 1))
            lg = lg[:, :self.cfg.vocab_size]
            if temperature > 0.0:
                key, k = jax.random.split(key)
                nxt = jax.random.categorical(k, lg / temperature, axis=-1)
            else:
                nxt = jnp.argmax(lg, axis=-1)
            cur = nxt[:, None].astype(jnp.int32)
            out.append(nxt)
        return GenerationResult(tokens=jnp.stack(out, axis=1), prompt_len=S)

    # ------------------------------------------------------------------

    @property
    def decode_compilations(self) -> int:
        """Total decode-step compilations across all live schedulers."""
        return sum(s.decode_compilations
                   for s in self._schedulers.values())

    def prefix_cache_stats(self):
        return {k: s.prefix_cache.stats
                for k, s in self._schedulers.items()}
