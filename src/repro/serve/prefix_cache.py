"""Sketch-gated prefix KV cache: count-min admission over prompt prefixes.

Production prompt streams are heavy-tailed — a few system/template prefixes
recur across millions of requests while the long tail is unique.  Caching
every prefill's KV would blow the budget on one-shot prompts, and tracking
exact per-prefix frequencies needs state proportional to unique-prompt
cardinality.  This module uses the same O(table)-storage hash machinery the
paper builds CS/FCS on (and that HCS motivates for multi-dimensional
lookups): prefix hashes are counted in a CSVec count-min table
(sketch/csvec.py, ``signed=False``), and a prefill's KV block is admitted to
the bounded cache only once its estimated frequency clears
``admit_threshold``.  Count-min's one-sided overestimate makes admission
*safe* — a hot prefix is never starved, a cold one is at worst admitted a
little early — while the tracker stays O(rows * cols) forever.

Granularity: block-multiple prefixes.  Every observed prompt increments the
count of each of its block-multiple prefixes in one batched
``accumulate_coords`` call, so two long prompts sharing a 32-token system
preamble both feed the same prefix keys even when their total lengths
differ.  Admission picks the LONGEST prefix over threshold.  Counts are
periodically aged (``decay``) TinyLFU-style so stale heavy hitters fade.

Eviction is plain LRU under a hard byte budget — the sketch gates what gets
*in*, the budget bounds what *stays*.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Optional, Tuple

import jax
import numpy as np

from repro.configs.base import ServeConfig
from repro.sketch import csvec

# count-min key domain: prefix hashes land in [0, CM_DOMAIN)
CM_DOMAIN = 1 << 20


def prefix_key(tokens: np.ndarray) -> int:
    """Stable 64-bit hash of a token prefix (process-salt-free)."""
    h = hashlib.blake2b(np.ascontiguousarray(tokens, np.int32).tobytes(),
                        digest_size=8)
    return int.from_bytes(h.digest(), "little")


@dataclass
class PrefixCacheStats:
    lookups: int = 0
    hits: int = 0
    misses: int = 0
    admitted: int = 0
    evicted: int = 0
    rejected: int = 0            # observed prefixes still under threshold
    bytes: int = 0

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.lookups, 1)


@dataclass
class _Entry:
    block: Any                   # np KV pytree, leaves (L, 1, plen, K, hd)
    nbytes: int


def _tree_nbytes(tree: Any) -> int:
    return sum(int(a.size) * int(a.dtype.itemsize)
               for a in jax.tree.leaves(tree))


@dataclass
class SketchPrefixCache:
    cfg: ServeConfig
    stats: PrefixCacheStats = field(default_factory=PrefixCacheStats)

    def __post_init__(self):
        self._cm = csvec.csvec_zeros(
            CM_DOMAIN, cols=self.cfg.cm_cols, rows=self.cfg.cm_rows,
            seed=self.cfg.seed, signed=False)
        self._entries: "OrderedDict[Tuple[int, ...], _Entry]" = OrderedDict()
        self._observed = 0

    # -- read path ---------------------------------------------------------
    def lookup(self, tokens: np.ndarray, max_suffix: Optional[int] = None
               ) -> Optional[Tuple[int, Any]]:
        """Longest cached block-multiple prefix of ``tokens``.  The engine
        chunk-prefills the remaining suffix at bucket granularity, so any
        suffix length is serviceable; pass ``max_suffix`` to cap it anyway
        (legacy forced-decode semantics).  Returns (prefix_len, np KV
        block) and refreshes LRU recency."""
        self.stats.lookups += 1
        block = self.cfg.prefix_block
        n = len(tokens)
        for m in range(n // block, 0, -1):
            plen = m * block
            if max_suffix is not None and n - plen > max_suffix:
                continue
            key = tuple(int(t) for t in tokens[:plen])
            ent = self._entries.get(key)
            if ent is not None:
                self._entries.move_to_end(key)
                self.stats.hits += 1
                return plen, ent.block
        self.stats.misses += 1
        return None

    # -- write path --------------------------------------------------------
    def _count(self, tokens: np.ndarray) -> Optional[np.ndarray]:
        """Increment the count-min frequency of every block-multiple
        prefix of ``tokens`` (one batched accumulate) and return the
        estimated counts, aging the table on the decay cadence."""
        block = self.cfg.prefix_block
        n_blocks = len(tokens) // block
        if n_blocks == 0:
            return None
        keys = np.array(
            [prefix_key(tokens[:m * block]) % CM_DOMAIN
             for m in range(1, n_blocks + 1)], np.int32)
        self._cm = csvec.accumulate_coords(
            self._cm, keys, np.ones(len(keys), np.float32))
        counts = np.asarray(csvec.query(self._cm, keys))
        self._observed += 1
        if self._observed % self.cfg.cm_decay_every == 0:
            self._cm = csvec.decay(self._cm, self.cfg.cm_decay)
        return counts

    def touch(self, tokens: np.ndarray) -> None:
        """Count a prompt that was served from the cache.  Hits must keep
        feeding the frequency sketch (classic TinyLFU counts every
        access): otherwise a steadily-hit prefix's count freezes, decays
        toward zero, and after an eventual LRU eviction the hottest
        prefix in the stream would have to re-earn admission from
        scratch."""
        self._count(tokens)

    def observe(self, tokens: np.ndarray) -> Optional[int]:
        """Count an observed (missed) prompt and return the longest
        prefix length whose estimated frequency clears the admission
        threshold and is not already cached — the caller should then
        ``admit`` its KV block.  Returns None when nothing qualifies."""
        counts = self._count(tokens)
        if counts is None:
            return None
        block = self.cfg.prefix_block
        n_blocks = len(counts)
        for m in range(n_blocks, 0, -1):
            if counts[m - 1] >= self.cfg.admit_threshold:
                key = tuple(int(t) for t in tokens[:m * block])
                if key not in self._entries:
                    return m * block
                return None          # longest qualifying prefix already in
        self.stats.rejected += 1
        return None

    def admit(self, tokens: np.ndarray, plen: int, kv_block: Any) -> None:
        """Store the KV block for ``tokens[:plen]`` (host copies, so the
        byte accounting is exact and entries survive donated device
        buffers), then evict LRU entries until under budget."""
        blk = jax.tree.map(lambda a: np.asarray(a), kv_block)
        nbytes = _tree_nbytes(blk)
        if nbytes > self.cfg.prefix_cache_bytes:
            return                   # one block can never fit: don't thrash
        key = tuple(int(t) for t in tokens[:plen])
        if key in self._entries:
            return
        self._entries[key] = _Entry(block=blk, nbytes=nbytes)
        self.stats.bytes += nbytes
        self.stats.admitted += 1
        while self.stats.bytes > self.cfg.prefix_cache_bytes:
            _, old = self._entries.popitem(last=False)
            self.stats.bytes -= old.nbytes
            self.stats.evicted += 1

    # -- introspection -----------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    def tracker_bytes(self) -> int:
        """Bytes held by the count-min frequency tracker (O(table),
        independent of how many unique prompts were observed)."""
        return csvec.state_bytes(self._cm)
