"""Sketch-gated prefix KV cache: count-min admission over prompt prefixes,
holding refcounted paged-pool block ids (zero-copy prefix sharing).

Production prompt streams are heavy-tailed — a few system/template prefixes
recur across millions of requests while the long tail is unique.  Caching
every prefill's KV would blow the budget on one-shot prompts, and tracking
exact per-prefix frequencies needs state proportional to unique-prompt
cardinality.  This module uses the same O(table)-storage hash machinery the
paper builds CS/FCS on (and that HCS motivates for multi-dimensional
lookups): prefix hashes are counted in a CSVec count-min table
(sketch/csvec.py, ``signed=False``), and a prefix is admitted to the
bounded cache only once its estimated frequency clears
``admit_threshold``.  Count-min's one-sided overestimate makes admission
*safe* — a hot prefix is never starved, a cold one is at worst admitted a
little early — while the tracker stays O(rows * cols) forever.

Granularity: block-multiple prefixes.  Every observed prompt increments the
count of each of its block-multiple prefixes in one batched
``accumulate_coords`` call, so two long prompts sharing a 32-token system
preamble both feed the same prefix keys even when their total lengths
differ.  Admission picks the LONGEST prefix over threshold.  Counts are
periodically aged (``decay``) TinyLFU-style so stale heavy hitters fade.

Storage: an admitted entry is a tuple of PHYSICAL POOL BLOCK IDS (the
slot's own prefill blocks, refcounted via the scheduler's BlockAllocator),
not a host copy — a hit writes the ids into the new slot's block table and
the prefix KV is shared by reference.  Eviction is LRU under a hard byte
budget counted in pool blocks, preferring entries no live slot still
references; an evicted entry's blocks return to the free list only when
their refcount reaches zero, so in-flight readers are never pulled out
from under.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.configs.base import ServeConfig
from repro.sketch import csvec

# count-min key domain: prefix hashes land in [0, CM_DOMAIN)
CM_DOMAIN = 1 << 20


def prefix_key(tokens: np.ndarray) -> int:
    """Stable 64-bit hash of a token prefix (process-salt-free)."""
    h = hashlib.blake2b(np.ascontiguousarray(tokens, np.int32).tobytes(),
                        digest_size=8)
    return int.from_bytes(h.digest(), "little")


@dataclass
class PrefixCacheStats:
    lookups: int = 0
    hits: int = 0
    misses: int = 0
    admitted: int = 0
    evicted: int = 0
    rejected: int = 0            # observed prompts yielding no new admission
    bytes: int = 0               # unique cache-held pool blocks * block size

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.lookups, 1)


@dataclass
class _Entry:
    plen: int                    # cached prefix length in tokens
    block_ids: Tuple[int, ...]   # physical pool blocks covering [0, plen)


@dataclass
class SketchPrefixCache:
    """``allocator`` is the scheduler's BlockAllocator: the cache holds one
    reference per (entry, block) and the allocator arbitrates frees.
    ``block_size`` is the paged-KV page size in tokens — admitted prefix
    lengths are multiples of it (whole shared blocks only: a partially
    filled block would expose rows another slot later rewrites)."""
    cfg: ServeConfig
    allocator: Any = None
    block_size: int = 0
    stats: PrefixCacheStats = field(default_factory=PrefixCacheStats)
    # optional repro.obs.ServeObserver: hit / miss / admit / evict /
    # defer outcomes stream into its windowed ``prefix.*`` counters
    # (``stats`` above stays the cumulative source of truth)
    obs: Any = None

    def __post_init__(self):
        # whole-block sharing needs admitted prefix lengths (multiples of
        # prefix_block) to be block-aligned; assert here so the cache's
        # own arithmetic may rely on it, not just the scheduler's check
        assert self.block_size > 0, "paged prefix cache needs a block size"
        assert self.cfg.prefix_block % self.block_size == 0, (
            self.cfg.prefix_block, self.block_size)
        self._cm = csvec.csvec_zeros(
            CM_DOMAIN, cols=self.cfg.cm_cols, rows=self.cfg.cm_rows,
            seed=self.cfg.seed, signed=False)
        self._entries: "OrderedDict[Tuple[int, ...], _Entry]" = OrderedDict()
        self._held: Dict[int, int] = {}      # block id -> # entries holding
        self._observed = 0

    # -- read path ---------------------------------------------------------
    def _find(self, tokens: np.ndarray
              ) -> Optional[Tuple[Tuple[int, ...], _Entry]]:
        """Longest cached block-multiple prefix (key, entry) of
        ``tokens``, no side effects."""
        block = self.cfg.prefix_block
        n = len(tokens)
        for m in range(n // block, 0, -1):
            # block-aligned by the __post_init__ divisibility invariant
            key = tuple(int(t) for t in tokens[:m * block])
            ent = self._entries.get(key)
            if ent is not None:
                return key, ent
        return None

    def peek(self, tokens: np.ndarray
             ) -> Optional[Tuple[int, Tuple[int, ...]]]:
        """Like ``lookup`` but WITHOUT touching stats or LRU recency —
        for retrying a deferred admission (pool pressure): the request
        was already counted on its first attempt, and counting retries
        would inflate frequencies/hit rates per scheduler round."""
        found = self._find(tokens)
        return None if found is None else (found[1].plen,
                                           found[1].block_ids)

    def lookup(self, tokens: np.ndarray
               ) -> Optional[Tuple[int, Tuple[int, ...]]]:
        """Longest cached block-multiple prefix of ``tokens``.  Returns
        (prefix_len, pool block ids) and refreshes LRU recency; the caller
        installs the ids into the slot's block table and takes its own
        allocator reference (zero-copy hit — no KV rows move)."""
        self.stats.lookups += 1
        found = self._find(tokens)
        if found is not None:
            key, ent = found
            self._entries.move_to_end(key)
            self.stats.hits += 1
            if self.obs is not None:
                self.obs.prefix_event("hit")
            return ent.plen, ent.block_ids
        self.stats.misses += 1
        if self.obs is not None:
            self.obs.prefix_event("miss")
        return None

    # -- write path --------------------------------------------------------
    def _count(self, tokens: np.ndarray) -> Optional[np.ndarray]:
        """Increment the count-min frequency of every block-multiple
        prefix of ``tokens`` (one batched accumulate) and return the
        estimated counts, aging the table on the decay cadence."""
        block = self.cfg.prefix_block
        n_blocks = len(tokens) // block
        if n_blocks == 0:
            return None
        keys = np.array(
            [prefix_key(tokens[:m * block]) % CM_DOMAIN
             for m in range(1, n_blocks + 1)], np.int32)
        self._cm = csvec.accumulate_coords(
            self._cm, keys, np.ones(len(keys), np.float32))
        counts = np.asarray(csvec.query(self._cm, keys))
        self._observed += 1
        if self._observed % self.cfg.cm_decay_every == 0:
            self._cm = csvec.decay(self._cm, self.cfg.cm_decay)
        return counts

    def observe(self, tokens: np.ndarray) -> Optional[int]:
        """Count an observed prompt — hits AND misses: classic TinyLFU
        counts every access, and a hot prompt that keeps hitting a short
        cached prefix must still be able to get its longer qualifying
        prefix admitted — and return the longest (kv-block-aligned) prefix
        length whose estimated frequency clears the admission threshold
        and is not already cached.  The caller should then ``admit`` the
        slot's pool blocks covering it.  Returns None (counting the prompt
        in ``stats.rejected``) when nothing new qualifies."""
        counts = self._count(tokens)
        if counts is None:           # sub-block prompt: nothing can ever
            self.stats.rejected += 1  # qualify, but the observation counts
            if self.obs is not None:
                self.obs.prefix_event("defer")
            return None
        block = self.cfg.prefix_block
        n_blocks = len(counts)
        for m in range(n_blocks, 0, -1):
            if counts[m - 1] >= self.cfg.admit_threshold:
                plen = m * block     # block-aligned by the init invariant
                key = tuple(int(t) for t in tokens[:plen])
                if key not in self._entries:
                    return plen
                # longest qualifying prefix already cached: nothing to
                # admit, but the observation still counts as rejected —
                # otherwise hot-and-cached prompts vanish from the stats
                break
        self.stats.rejected += 1
        if self.obs is not None:
            self.obs.prefix_event("defer")
        return None

    def admit(self, tokens: np.ndarray, plen: int,
              block_ids: Tuple[int, ...]) -> None:
        """Hold a reference on the pool blocks covering ``tokens[:plen]``
        (zero-copy: they are the admitting slot's own prefill blocks),
        then evict LRU entries until under the byte budget.  Re-admitting
        a present key refreshes its LRU recency instead of silently
        returning — eviction order must reflect real access order."""
        assert plen % self.block_size == 0, (plen, self.block_size)
        assert len(block_ids) == plen // self.block_size
        key = tuple(int(t) for t in tokens[:plen])
        if key in self._entries:
            self._entries.move_to_end(key)
            return
        bb = self.allocator.block_bytes
        if len(block_ids) * bb > self.cfg.prefix_cache_bytes:
            return                   # one entry can never fit: don't thrash
        # every admitted block must still be LIVE (held by the admitting
        # slot): a freed block id would be ref'd back to life here while
        # the allocator hands the same block to someone else — the cache
        # would then serve rows another slot is overwriting.  Sketched
        # slots fold-and-free leading prompt blocks, so the scheduler
        # must skip admission for them rather than trip this.
        rc = self.allocator.rc
        assert all(int(rc[b]) >= 1 for b in block_ids), (
            "prefix-cache admit of freed block(s): "
            f"{[b for b in block_ids if int(rc[b]) < 1]}")
        self.allocator.ref(block_ids)
        for b in block_ids:
            self._held[b] = self._held.get(b, 0) + 1
        self._entries[key] = _Entry(plen=plen, block_ids=tuple(block_ids))
        self.stats.bytes = len(self._held) * bb
        self.stats.admitted += 1
        if self.obs is not None:
            self.obs.prefix_event("admit")
        while self.stats.bytes > self.cfg.prefix_cache_bytes:
            if not self.evict_one():
                break

    # -- eviction ----------------------------------------------------------
    def _entry_busy(self, ent: _Entry) -> bool:
        """True if any live slot still references the entry's blocks
        (allocator refcount above the cache's own holds)."""
        rc = self.allocator.rc
        return any(int(rc[b]) > self._held.get(b, 0)
                   for b in ent.block_ids)

    def _remove(self, key: Tuple[int, ...]) -> None:
        ent = self._entries.pop(key)
        for b in ent.block_ids:
            self._held[b] -= 1
            if self._held[b] == 0:
                del self._held[b]
        self.allocator.unref(ent.block_ids)
        self.stats.bytes = len(self._held) * self.allocator.block_bytes
        self.stats.evicted += 1
        if self.obs is not None:
            self.obs.prefix_event("evict")

    def evict_one(self, idle_only: bool = False) -> bool:
        """Evict one entry in LRU order, preferring entries whose blocks
        no live slot references (those actually free pool blocks).
        ``idle_only`` stops there — the pool-pressure caller gains
        nothing from evicting busy entries (their blocks stay reserved by
        the referencing slots), so wiping hot cached prefixes would be
        pure loss.  The byte-budget caller falls back to the absolute LRU
        entry: its blocks return to the free list when the last
        referencing slot retires, which is what the budget needs.
        Returns False when nothing (eligible) remains."""
        if not self._entries:
            return False
        for key, ent in self._entries.items():
            if not self._entry_busy(ent):
                self._remove(key)
                return True
        if idle_only:
            return False
        self._remove(next(iter(self._entries)))
        return True

    # -- introspection -----------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    def held_blocks(self) -> int:
        """Unique pool blocks currently held by the cache."""
        return len(self._held)

    def tracker_bytes(self) -> int:
        """Bytes held by the count-min frequency tracker (O(table),
        independent of how many unique prompts were observed)."""
        return csvec.state_bytes(self._cm)
