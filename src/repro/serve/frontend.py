"""Async serving front-end: an always-on admission/prefill/decode pump
over the slot scheduler's phase API.

``ServeEngine.generate`` is batch-in/batch-out; production traffic is an
open stream — requests arrive one at a time, consumers want tokens as
they commit, clients hang up, and some requests matter more than others.
``AsyncServeEngine`` exposes that shape:

    engine = AsyncServeEngine(cfg, params)
    handle = await engine.submit(prompt, max_new=64, priority=1,
                                 deadline_s=2.0)
    async for tok in handle.stream():
        ...                         # tokens as each decode chunk lands
    handle.cancel()                 # mid-flight: slot + blocks free now
    completion = await handle.result()

One asyncio task (the PUMP) owns the scheduler.  Each iteration:

    1. pump boundary: apply queued cancellations, expire deadlines
       (both ride slot-retire + block-free — CoW forks and folded
       tails already make mid-flight eviction safe);
    2. ``admit_pending()`` — queued requests into free slots, possibly
       preempting strictly lower-priority running slots;
    3. ``dispatch()`` — the compiled decode chunk launches and returns
       device FUTURES immediately;
    4. overlap: the pump yields to the event loop, so new submissions
       land and a second ``admit_pending()`` runs THEIR host-side
       bookkeeping and chunked prefill while the device crunches (the
       in-flight chunk read pre-admission state: an idle slot emits
       nothing and its sentinel table row drops the KV write, and the
       prefill ops enqueue after the chunk in device-stream order);
    5. ``collect()`` — run in a worker thread so the event loop stays
       live while the host blocks on the chunk — then per-token deltas
       fan out to handle queues and finished requests resolve.

The pump task exits when the scheduler drains and restarts on the next
submit, so ``asyncio.run`` driver loops never leak a pending task.

Backpressure: ``submit`` awaits while ``cfg.serve.queue_depth`` requests
are already queued — it defers, it NEVER raises — so an open-loop
arrival process can't grow host state without bound; the bound is the
admission queue, the pool pressure story is unchanged (admission defers
until blocks free).

Ordering / identity: admission order is the scheduler's priority-banded
FIFO, decode runs the same one-compilation-per-engine chunk, and a
greedy request's streamed tokens are BITWISE the tokens
``ServeEngine.generate`` returns for the same prompt set — the batch
facade is a thin wrapper over this class.
"""
from __future__ import annotations

import asyncio
import time
from typing import Any, AsyncIterator, Dict, List, Optional, Set, Union

import jax
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig
from repro.models.draft import Draft
from repro.serve.scheduler import (Completion, EngineStats, Request,
                                   SlotScheduler)


class StreamHandle:
    """Caller-side view of one submitted request: an async token stream,
    a result future, and a cancel switch.  ``tokens`` accumulates what
    ``stream()`` has yielded so far; ``completion`` is set once the
    request finishes (any status)."""

    def __init__(self, engine: "AsyncServeEngine", req: Request):
        self._engine = engine
        self.rid = req.rid
        self.prompt_len = len(req.tokens)
        self.tokens: List[int] = []
        self.completion: Optional[Completion] = None
        self._delivered = 0                    # pump-side watermark
        self._queue: "asyncio.Queue[Union[int, Completion]]" = \
            asyncio.Queue()
        self._done = asyncio.Event()

    @property
    def done(self) -> bool:
        return self.completion is not None

    async def stream(self) -> AsyncIterator[int]:
        """Yield tokens as the pump commits them (chunk granularity —
        ``cfg.serve.decode_chunk`` steps per delivery).  Ends when the
        request completes, cancels or expires; ``completion`` is set by
        then.  One streaming consumer per handle; ``result()`` may be
        awaited concurrently (it watches completion, it does not
        compete for the stream)."""
        while True:
            item = await self._queue.get()
            if isinstance(item, Completion):
                return
            self.tokens.append(item)
            yield item

    async def result(self) -> Completion:
        """Await the request's resolution and return the Completion
        (``completion.tokens`` is the full committed output regardless
        of what any stream consumer has pulled so far).  Safe alongside
        a concurrent ``stream()`` iterator."""
        await self._done.wait()
        return self.completion

    def cancel(self) -> None:
        """Request cancellation: applied at the next pump boundary —
        the slot retires, its pool blocks free, and the stream ends
        with a ``status == "cancelled"`` Completion holding whatever
        tokens were committed.  Idempotent; a no-op once done."""
        if self.completion is None:
            self._engine._cancel_rids.add(self.rid)


class AsyncServeEngine:
    """The async front door.  Either owns a fresh ``SlotScheduler``
    (``cfg`` + ``params``) or wraps an existing one (``scheduler=`` —
    how ``ServeEngine.generate`` reuses its cached, warmed-up
    scheduler).  All methods must be called from a single asyncio event
    loop at a time; the pump recreates its primitives when driven from
    a fresh ``asyncio.run``."""

    def __init__(self, cfg: Optional[ModelConfig] = None,
                 params: Any = None,
                 serve: Optional[ServeConfig] = None,
                 scheduler: Optional[SlotScheduler] = None,
                 temperature: float = 0.0,
                 draft: Optional[Draft] = None,
                 obs: Any = None):
        if scheduler is not None:
            self._sched = scheduler
        else:
            assert cfg is not None and params is not None, (
                "AsyncServeEngine needs (cfg, params) or scheduler=")
            self._sched = SlotScheduler(cfg, params, serve=serve,
                                        temperature=temperature,
                                        draft=draft)
        # observability (repro.obs.ServeObserver or None): an explicit
        # observer attaches to the wrapped scheduler too; otherwise the
        # front-end adopts whatever the scheduler already carries, so
        # front-end hooks (TTFT/ITL, pump spans, backpressure) and
        # scheduler hooks always land in the SAME observer
        if obs is not None:
            self._sched.set_observer(obs)
        self.obs = obs if obs is not None \
            else getattr(self._sched, "obs", None)
        sv = self._sched.serve
        self.queue_depth = max(1, int(sv.queue_depth))
        self.default_deadline_s = float(sv.default_deadline_s)
        self._handles: Dict[int, StreamHandle] = {}
        self._cancel_rids: Set[int] = set()
        self._rid = 0
        self._pump_task: Optional[asyncio.Task] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._space: Optional[asyncio.Event] = None

    # -- submission ----------------------------------------------------

    async def submit(self, tokens, max_new: int = 32, *,
                     temperature: Optional[float] = None, top_k: int = 0,
                     seed: Optional[int] = None,
                     key: Optional[jax.Array] = None,
                     spec_k: Optional[int] = None,
                     kv_sketch: Optional[bool] = None,
                     priority: int = 0,
                     deadline_s: Optional[float] = None,
                     rid: Optional[int] = None) -> StreamHandle:
        """Submit one request; returns its StreamHandle.  Blocks (never
        raises) while ``queue_depth`` requests are already waiting —
        open-loop backpressure.  ``deadline_s`` is a relative SLO from
        now (None -> ``cfg.serve.default_deadline_s``; 0 disables);
        ``priority`` orders admission and arms preemption.  ``rid``
        overrides the engine's counter (the batch facade threads its
        own ids through so key derivation matches)."""
        self._ensure_loop()
        stalled_at = None
        while self._sched.queue_len >= self.queue_depth:
            if stalled_at is None:
                stalled_at = time.perf_counter()
            self._space.clear()
            await self._space.wait()
        if stalled_at is not None and self.obs is not None:
            self.obs.backpressure_wait(time.perf_counter() - stalled_at)
        if rid is None:
            rid = self._rid
            self._rid += 1
        else:
            self._rid = max(self._rid, rid + 1)
        ds = (self.default_deadline_s if deadline_s is None
              else float(deadline_s))
        deadline = time.monotonic() + ds if ds and ds > 0 else None
        req = Request(rid=rid, tokens=np.asarray(tokens, np.int32),
                      max_new=int(max_new), temperature=temperature,
                      top_k=int(top_k), seed=seed, key=key,
                      spec_k=spec_k, kv_sketch=kv_sketch,
                      priority=int(priority), deadline=deadline)
        self._sched.submit(req)
        handle = StreamHandle(self, req)
        self._handles[rid] = handle
        # start (or restart) the pump only AFTER the request is queued:
        # a pump that wakes to an empty scheduler exits immediately
        self._ensure_pump()
        return handle

    async def drain(self) -> None:
        """Wait until every submitted request has resolved (the pump
        exits when the scheduler empties)."""
        while self._pump_task is not None and not self._pump_task.done():
            await self._pump_task

    async def aclose(self) -> None:
        """Cancel everything still queued or in flight and stop."""
        for h in list(self._handles.values()):
            h.cancel()
        await self.drain()

    def stats(self) -> EngineStats:
        return self._sched.stats()

    # -- the pump ------------------------------------------------------

    def _ensure_loop(self) -> None:
        """Bind (or rebind) to the running event loop: asyncio
        primitives don't survive across ``asyncio.run`` calls."""
        loop = asyncio.get_running_loop()
        if self._loop is not loop:
            self._loop = loop
            self._space = asyncio.Event()
            self._pump_task = None      # task belonged to the old loop

    def _ensure_pump(self) -> None:
        if self._pump_task is None or self._pump_task.done():
            self._pump_task = self._loop.create_task(self._pump())

    async def _pump(self) -> None:
        sched = self._sched
        obs = self.obs
        while True:
            # pump boundary: no chunk in flight — evictions are safe
            self._apply_cancels()
            for c in sched.expire_deadlines():
                self._finish(c)
            self._notify_space()
            sched.admit_pending()
            t0 = time.perf_counter()
            if not sched.dispatch():
                if not sched.pending:
                    return              # drained; next submit restarts
                await asyncio.sleep(0)  # transient: let submitters run
                continue
            if obs is not None:
                # host time spent launching the chunk (jax dispatch +
                # fold planning) — the device work is still in flight
                obs.pump_span("dispatch", t0, time.perf_counter() - t0)
            # overlap window: the chunk is crunching on-device; yield so
            # fresh submissions land, then run THEIR admission/prefill
            # host work now instead of serializing after collect
            await asyncio.sleep(0)
            sched.admit_pending()
            t0 = time.perf_counter()
            done = await asyncio.to_thread(sched.collect)
            if obs is not None:
                # wall time blocked on the chunk's one device sync
                obs.pump_span("collect", t0, time.perf_counter() - t0)
            self._deliver_progress()
            for c in done:
                self._finish(c)
            self._notify_space()
            # let consumers react to the tokens just delivered BEFORE
            # the next boundary, so a cancel() they issue now applies
            # ahead of the next dispatch instead of one chunk later
            await asyncio.sleep(0)

    def _apply_cancels(self) -> None:
        while self._cancel_rids:
            rid = self._cancel_rids.pop()
            c = self._sched.cancel(rid)
            if c is not None:
                self._finish(c)

    def _deliver_progress(self) -> None:
        """Fan freshly committed tokens out to their handles."""
        for rid, toks in self._sched.progress().items():
            h = self._handles.get(rid)
            if h is None or len(toks) <= h._delivered:
                continue
            for t in toks[h._delivered:]:
                h._queue.put_nowait(int(t))
            if self.obs is not None:
                self.obs.tokens_delivered(rid, len(toks) - h._delivered)
            h._delivered = len(toks)

    def _finish(self, c: Completion) -> None:
        h = self._handles.pop(c.rid, None)
        if h is None:
            return
        total = [int(t) for t in c.tokens]
        if self.obs is not None and len(total) > h._delivered:
            self.obs.tokens_delivered(c.rid, len(total) - h._delivered)
        for t in total[h._delivered:]:
            h._queue.put_nowait(t)
        h._delivered = len(total)
        h.completion = c
        h._queue.put_nowait(c)      # terminates the stream() iterator
        h._done.set()               # resolves result() awaiters

    def _notify_space(self) -> None:
        if self._space is not None and \
                self._sched.queue_len < self.queue_depth:
            self._space.set()
