"""Continuous-batching slot scheduler over preallocated per-slot state.

The engine owns ``max_batch`` slots.  For attention families the KV cache
is PAGED: one shared (L, num_kv_blocks, kv_block_size, K, hd) block pool
plus a per-slot (blocks_per_slot,) int32 block table — a request reserves
ceil((S + max_new) / kv_block_size) pool blocks from a host-side free-list
allocator instead of max_seq dense rows, so long-tail requests stop
reserving sequence capacity they never touch.  For recurrent families
(ssm / hybrid) the slot state is the family's per-layer recurrent state
stacked on the slot axis ((L, max_batch, ...) leaves, plus the hybrid
shared-KV rows), exactly as before.  Decode runs as ONE jitted function
for the engine's lifetime: a ``jax.lax.scan`` of single-token steps over
fixed shapes, with per-slot position / active masks, per-slot sampling
parameters, and (for attention) per-slot block tables doing the work that
used to require per-request shapes.  Requests of arbitrary (mixed) prompt
lengths, families and sampling settings are admitted into free slots
between chunks and retired when their token budget is spent; the decode
step therefore compiles exactly once per engine (see
``decode_compilations``).

Prefill:

  * attention families (dense / moe / audio / vlm) use CHUNKED prefill:
    the prompt is fed through ``tf.prefill_chunk`` in ``prefill_bucket``-
    sized chunks scattered through the slot's block table into the pool,
    each chunk attending against everything below it.  Chunk starts are
    absolute multiples of the bucket — never clamped — so a prefix-cache
    hit resuming at ``plen`` replays exactly the chunk boundaries a cold
    miss used (the overlap recompute is idempotent) and the two paths
    produce bitwise-identical cache rows and tokens; rows a tail chunk
    would write past the request's reserved blocks map to the invalid
    table sentinel and are dropped by the scatter.  Table and offset are
    traced, so prefill compiles exactly once too, for any prompt length.
  * recurrent families prefill the first S-1 prompt tokens exactly (no
    padding — trailing pad tokens would corrupt a recurrence) and insert
    the resulting state wholesale into the slot (the slot "reset"); the
    last prompt token is fed through the first decode step, which advances
    the state and samples the first output in-graph.  Prefill compiles per
    distinct prompt length, as the synchronized fallback always did.

Slot-uniform decode semantics (all shape-static):

  * every slot decodes every step; inactive slots mutate nothing: a
    retired slot's block-table row is reset to the invalid sentinel, so
    its idle KV write is dropped by the scatter — pool blocks are safe to
    free and reuse the moment their refcount hits zero.  Recurrent slot
    state is replaced wholesale at the next admit, so junk there is never
    observed.
  * a freshly admitted attention-family request resumes at
    ``pos = S - 1`` by re-feeding its last prompt token: the recomputed KV
    row is bit-identical (it depends only on that token's residual stream)
    and the resulting logits sample the first output token in-graph —
    prefill logits never cross the host boundary.  When the whole prompt
    was a cached prefix the rewrite lands in a SHARED block; it is
    idempotent, so concurrent readers of that block see unchanged bits.
  * sampling is per-slot: temperature / top-k / PRNG key live in (B,)
    engine state set at admission, so greedy and sampled requests (and
    different seeds) share the one compiled chunk.  A greedy slot's tokens
    are bitwise-independent of its neighbours.

Prefix reuse (attention families only — a recurrent state at a prefix
boundary is not recoverable from an end-of-prompt prefill) is gated by the
count-min admission filter in serve/prefix_cache.py and is ZERO-COPY: a
hit writes the cached entry's physical block ids into the new slot's
table and bumps their refcounts; no KV rows move.  Admission donates the
admitting slot's own prefill blocks to the cache the same way.

Copy-on-write: in a SPECULATIVE engine a slot never decodes into a block
whose refcount is above one.  After hit installation and admission
donation, any shared block the slot's decode region [S - 1, ...) reaches
is forked — a fresh pool block is allocated, the rows are copied
device-side (target and draft pools alike), the table entry is rebound
and the shared block loses one reference (``_ensure_exclusive``).  Plain
engines skip the fork: their only shared-block write is the idempotent
last-prompt-token rewrite.  Speculative verify writes draft proposals
that may be REJECTED, so there the fork rule is what makes "a cached
prefix entry's blocks are immutable while cached" hold.

Speculative decoding (``serve.spec_k > 0`` / per-request
``Request.spec_k``, attention families): a derived draft model
(``models/draft.py`` — truncated and/or count-sketch-compressed) runs a
K-token greedy proposal loop per slot inside the SAME compiled chunk,
writing its own shallow paged pool through the slot's block table, and
the target verifies all K+1 positions in one multi-query decode
(``tf.verify_step``).  The accepted prefix commits (per-slot position
advance), rejection rolls the slot back simply by not advancing —
rejected rows sit above the slot's position and are overwritten by the
next round before any query can attend them.  Greedy speculative output
is token-for-token identical to plain greedy decode; sampled slots fall
back to one verified token per round drawn with their own key.  A spec
engine reserves ``spec_k`` extra rows per slot so overhang writes stay
inside the slot's own blocks.

The host loop is decomposed into PUMP PHASES — ``admit_pending`` /
``dispatch`` / ``collect``, plus ``cancel`` / ``preempt`` /
``expire_deadlines`` at pump boundaries.  ``step()`` composes them
synchronously (the classic closed-batch round); serve/frontend.py's
``AsyncServeEngine`` drives them as an always-on pump instead:
``dispatch`` launches the compiled chunk asynchronously (jax returns
futures), host-side admission and chunked prefill run while the device
crunches, and ``collect`` is the round's single host-device sync point.
Retirement and fold planning read HOST mirrors of the per-slot position
and budget — never device arrays — so the overlap is real.  SLO
scheduling rides the same machinery: per-request ``priority`` orders
the admission queue, ``deadline`` expires requests (queued or
mid-flight, surfacing partial output), and a full engine preempts a
strictly lower-priority slot by requeueing it as a continuation whose
prompt carries the tokens already served.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from dataclasses import dataclass
from typing import Any, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig
from repro.kernels import ops as kops
from repro.models import transformer as tf
from repro.models.draft import Draft, make_draft
from repro.serve import kv_sketch as kvs
from repro.serve.prefix_cache import SketchPrefixCache
from repro.serve.speculative import build_spec_chunk
from repro.serve.speculative import round_accounting as \
    spec_round_accounting

KV_FAMILIES = ("dense", "moe", "audio", "vlm")
RECURRENT_FAMILIES = ("ssm", "hybrid")


@dataclass
class Request:
    rid: int
    tokens: np.ndarray           # (S,) int32 prompt
    max_new: int
    # per-request sampling: None temperature falls back to the scheduler
    # default; top_k == 0 disables top-k filtering.  The slot PRNG key is
    # ``key`` when given, else PRNGKey(seed), else derived from the
    # scheduler's base key and the rid.
    temperature: Optional[float] = None
    top_k: int = 0
    seed: Optional[int] = None
    key: Optional[jax.Array] = None
    # speculative tokens per round: None -> the engine default
    # (cfg.serve.spec_k); clamped to the engine max; 0 = plain decode for
    # this request even inside a speculative engine.
    spec_k: Optional[int] = None
    # sketched long-context KV (serve/kv_sketch.py): None follows the
    # engine (on when cfg.serve.kv_sketch_window > 0); False opts this
    # request out — it reserves full exact coverage and never folds.
    kv_sketch: Optional[bool] = None
    # SLO scheduling (serve/frontend.py): higher priority admits first
    # and may preempt strictly lower-priority running slots when the
    # engine is full (cfg.serve.preemption); ``deadline`` is an absolute
    # time.monotonic() timestamp past which the request is expired —
    # dropped from the queue, or retired mid-flight with whatever tokens
    # it has (Completion.status == "expired").
    priority: int = 0
    deadline: Optional[float] = None


@dataclass
class Completion:
    rid: int
    prompt_len: int
    tokens: np.ndarray           # (<= max_new,) int32 generated
    prefix_hit: bool
    # "ok" — full budget served; "cancelled" — caller cancelled
    # mid-flight; "expired" — deadline passed (tokens hold the partial
    # output in both non-ok cases).  Preemption never surfaces here: a
    # preempted request is requeued as a continuation and completes "ok".
    status: str = "ok"


def _stat(kind: str) -> Any:
    """An ``EngineStats`` field tagged with its merge/metrics KIND:

      "counter"  monotonic event count — merges by SUM, and windowed
                 metric deltas of it sum back to the cumulative total;
      "gauge"    instantaneous level over resources the scheduler OWNS
                 (its queue, its slots, its pool blocks) — schedulers
                 in one engine own disjoint resources, so a merged
                 engine-level gauge is the sum of the per-scheduler
                 gauges (a documented disjoint-sum, not double
                 counting);
      "peak"     high-water mark — merges by MAX (summing peaks of
                 independently-peaking schedulers would report a
                 moment that never existed);
      "geometry" a configuration constant — merges by MAX so the
                 merged snapshot stays printable.

    The same tags drive ``repro.obs.MetricsRegistry.update_from_stats``,
    so merge semantics and metrics semantics can never drift apart.
    """
    return dataclasses.field(default=0, metadata={"kind": kind})


@dataclass
class EngineStats:
    """One flat observability snapshot of a scheduler (or, merged, of a
    whole engine): queue pressure, slot occupancy, pool high-water
    marks, prefix-cache effectiveness, sketch folding and speculative
    acceptance — everything launch/serve.py prints at exit and the
    async front-end exposes for monitoring.  ``merge`` combines
    snapshots across schedulers per-field by each field's tagged kind
    (counters sum, gauges disjoint-sum, peaks max — see ``_stat``);
    ratio properties recompute from the merged counts.

    ``queue_depth`` never double-counts: each scheduler owns exactly
    one admission queue, and the async front-end
    (``AsyncServeEngine``) wraps exactly ONE scheduler — its bounded
    queue IS that scheduler's queue.  ``ServeEngine`` keeps one
    scheduler per batch-size family, each with its own (disjoint)
    queue, so the merged depth is the true number of waiting requests
    across the engine."""
    queue_depth: int = _stat("gauge")
    active_slots: int = _stat("gauge")
    max_batch: int = _stat("gauge")           # total slots across parts
    completed: int = _stat("counter")   # all statuses, incl. the below
    cancelled: int = _stat("counter")
    expired: int = _stat("counter")
    preempted: int = _stat("counter")   # preemptions (requests requeued)
    decode_steps: int = _stat("counter")
    decode_compilations: int = _stat("counter")
    prefill_compilations: int = _stat("counter")
    pool_blocks: int = _stat("gauge")         # pool sizes are disjoint
    block_size: int = _stat("geometry")
    blocks_reserved: int = _stat("gauge")
    blocks_free: int = _stat("gauge")
    blocks_peak: int = _stat("peak")
    kv_reserved_bytes: int = _stat("gauge")
    kv_peak_reserved_bytes: int = _stat("peak")
    kv_peak_used_bytes: int = _stat("peak")
    kv_dense_equiv_bytes: int = _stat("gauge")
    prefix_lookups: int = _stat("counter")
    prefix_hits: int = _stat("counter")
    prefix_admitted: int = _stat("counter")
    prefix_evicted: int = _stat("counter")
    prefix_cached_bytes: int = _stat("gauge")
    fold_rows: int = _stat("counter")   # exact rows folded into tails
    kv_sketch_tail_bytes: int = _stat("gauge")
    spec_rounds: int = _stat("counter")
    spec_proposed: int = _stat("counter")
    spec_accepted: int = _stat("counter")

    @property
    def prefix_hit_rate(self) -> float:
        return self.prefix_hits / max(self.prefix_lookups, 1)

    @property
    def acceptance_rate(self) -> float:
        return self.spec_accepted / max(self.spec_proposed, 1)

    @property
    def mean_accepted_run(self) -> float:
        return ((self.spec_accepted + self.spec_rounds)
                / max(self.spec_rounds, 1))

    @staticmethod
    def field_kinds() -> Dict[str, str]:
        """field name -> kind tag ("counter" / "gauge" / "peak" /
        "geometry"); the single source of truth shared by ``merge`` and
        the metrics registry's EngineStats bridge."""
        return {f.name: f.metadata.get("kind", "counter")
                for f in dataclasses.fields(EngineStats)}

    @staticmethod
    def merge(parts: Sequence["EngineStats"]) -> "EngineStats":
        """Merge per-scheduler snapshots kind-correctly: counters and
        gauges sum (gauges measure disjoint resources — see the class
        docstring), peaks and geometry take the max (each scheduler's
        high-water mark happened at its own moment; summing them would
        fabricate a combined peak that never occurred)."""
        out = EngineStats()
        for p in parts:
            for f in dataclasses.fields(EngineStats):
                kind = f.metadata.get("kind", "counter")
                if kind in ("peak", "geometry"):
                    setattr(out, f.name,
                            max(getattr(out, f.name), getattr(p, f.name)))
                else:
                    setattr(out, f.name,
                            getattr(out, f.name) + getattr(p, f.name))
        return out

    def format(self) -> str:
        """Human-readable multi-line report (the launch driver's exit
        summary)."""
        lines = [
            f"queue={self.queue_depth} active={self.active_slots}/"
            f"{self.max_batch} completed={self.completed} "
            f"(cancelled={self.cancelled} expired={self.expired} "
            f"preemptions={self.preempted})",
            f"decode: steps={self.decode_steps} "
            f"compilations={self.decode_compilations} "
            f"(prefill: {self.prefill_compilations})",
        ]
        if self.pool_blocks:
            lines.append(
                f"paged KV: {self.pool_blocks} blocks x "
                f"{self.block_size} tokens, reserved="
                f"{self.blocks_reserved} (peak {self.blocks_peak}, "
                f"free {self.blocks_free}) — "
                f"{self.kv_peak_reserved_bytes}B peak vs dense "
                f"{self.kv_dense_equiv_bytes}B")
            lines.append(
                f"prefix cache: hit_rate={self.prefix_hit_rate:.2f} "
                f"({self.prefix_hits}/{self.prefix_lookups}), "
                f"admitted={self.prefix_admitted}, "
                f"evicted={self.prefix_evicted}, "
                f"cached_bytes={self.prefix_cached_bytes}")
        if self.fold_rows or self.kv_sketch_tail_bytes:
            lines.append(
                f"kv sketch: folded_rows={self.fold_rows}, "
                f"tail_bytes={self.kv_sketch_tail_bytes}")
        if self.spec_rounds:
            lines.append(
                f"speculative: acceptance={self.acceptance_rate:.2f} "
                f"({self.spec_accepted}/{self.spec_proposed}), "
                f"mean_run={self.mean_accepted_run:.2f} over "
                f"{self.spec_rounds} rounds")
        return "\n".join(lines)


class BlockAllocator:
    """Host-side free-list allocator over the paged KV block pool.

    Every pool block has a refcount: 1 for the slot that allocated it,
    +1 per prefix-cache entry holding it, +1 per additional slot sharing
    it through a prefix hit.  A block returns to the free list exactly
    when its count reaches zero — zero-copy sharing with no
    use-after-free, no matter how admission, hits and evictions
    interleave.  ``peak_reserved`` records the high-water mark of
    allocated blocks (the paged analogue of the dense cache's
    max_batch * max_seq reservation).
    """

    def __init__(self, num_blocks: int, block_bytes: int):
        self.num_blocks = num_blocks
        self.block_bytes = block_bytes
        self._free = list(range(num_blocks - 1, -1, -1))   # pop() -> 0,1,..
        self.rc = np.zeros((num_blocks,), np.int64)
        self.peak_reserved = 0

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def reserved(self) -> int:
        return self.num_blocks - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Take ``n`` blocks (refcount 1 each); None if not enough free."""
        if n > len(self._free):
            return None
        ids = [self._free.pop() for _ in range(n)]
        self.rc[ids] += 1
        self.peak_reserved = max(self.peak_reserved, self.reserved)
        return ids

    def ref(self, ids: Sequence[int]) -> None:
        for b in ids:
            self.rc[b] += 1

    def unref(self, ids: Sequence[int]) -> None:
        for b in ids:
            self.rc[b] -= 1
            assert self.rc[b] >= 0, f"block {b} over-unreffed"
            if self.rc[b] == 0:
                self._free.append(b)

    def fork(self, b: int) -> Optional[int]:
        """Copy-on-write preparation for one holder of block ``b``: when
        the caller is the sole holder (refcount 1) the block is returned
        unchanged; otherwise a fresh block is taken (refcount 1), the
        caller's reference on ``b`` is dropped, and the new id returned —
        the caller then copies the rows device-side and rebinds its
        table.  None when the pool has no free block (caller defers)."""
        assert self.rc[b] >= 1, f"fork of unheld block {b}"
        if self.rc[b] == 1:
            return b
        ids = self.alloc(1)
        if ids is None:
            return None
        self.rc[b] -= 1          # rc > 1: never reaches the free list here
        return ids[0]

    def reserved_bytes(self) -> int:
        return self.reserved * self.block_bytes

    def peak_reserved_bytes(self) -> int:
        return self.peak_reserved * self.block_bytes


class DecodeState(NamedTuple):
    """All device-resident engine state (a pytree; see
    launch.shardings.serve_state_pspecs for its mesh placement)."""
    cache: Dict[str, Any]        # KV block pool / recurrent slot state
                                 # (+ "draft" sub-pool in a spec engine)
    tables: jax.Array            # (B, blocks_per_slot) int32 block tables
    cur: jax.Array               # (B, 1) next token to feed per slot
    pos: jax.Array               # (B,)  write/attend position per slot
    remaining: jax.Array         # (B,)  output tokens still owed per slot
    temp: jax.Array              # (B,)  sampling temperature per slot
    top_k: jax.Array             # (B,)  top-k cutoff per slot (0 = off)
    keys: jax.Array              # (B, 2) per-slot sampling PRNG keys
    spec_k: jax.Array            # (B,)  speculative proposals per round
    fold_base: jax.Array         # (B,)  rows folded into the slot's FCS
                                 # tail (0 = nothing folded, pure exact)


class SlotScheduler:
    def __init__(self, cfg: ModelConfig, params: Any,
                 serve: Optional[ServeConfig] = None,
                 temperature: float = 0.0,
                 draft: Optional[Draft] = None,
                 obs: Any = None):
        if cfg.family not in KV_FAMILIES + RECURRENT_FAMILIES:
            raise ValueError(f"unknown family {cfg.family!r}")
        self.cfg = cfg
        self.params = params
        self.serve = serve if serve is not None else cfg.serve
        self.temperature = float(temperature)   # default for requests
        self.is_kv = cfg.family in KV_FAMILIES
        sv = self.serve
        # paged-attention implementation, resolved ONCE to a static bool
        # (None = auto: Pallas kernels on TPU, jnp gather path elsewhere)
        # and baked into every compiled chunk below — layers never
        # re-detect, so the chunks stay one-compilation-per-engine
        self.use_kernels = (kops.default_use_pallas()
                            if sv.paged_kernels is None
                            else bool(sv.paged_kernels)) and self.is_kv
        B = sv.max_batch
        # speculative decode: an explicit draft wins; else derive one per
        # the serve knobs (None when spec_k == 0 or the family has no KV)
        self.draft = (draft if draft is not None
                      else make_draft(params, cfg, sv))
        self.spec_max = (int(sv.spec_k)
                         if self.is_kv and self.draft is not None else 0)
        if self.draft is not None and not self.is_kv:
            raise ValueError("speculative decode needs a kv-cache family")
        self.spec_rounds = 0       # verify rounds run by speculating slots
        self.spec_proposed = 0     # draft tokens proposed in those rounds
        self.spec_accepted = 0     # draft tokens verified-and-emitted
        self._queue: List[Request] = []
        self._slot_req: List[Optional[Request]] = [None] * B
        self._slot_out: List[List[int]] = [[] for _ in range(B)]
        self._slot_hit: List[bool] = [False] * B
        self._slot_blocks: List[List[int]] = [[] for _ in range(B)]
        self._slot_spec: List[int] = [0] * B
        # rid -> pending admit_plen: set on a request's FIRST admission
        # attempt so pool-pressure retries don't re-feed the count-min
        # tracker (a queued one-shot prompt must not accrue one count per
        # scheduler round and spuriously cross admit_threshold)
        self._admit_memo: Dict[int, Optional[int]] = {}
        self._slot_rows: List[int] = [0] * B
        # host mirrors of device per-slot state, maintained at admission
        # and collect(): the pump phases (fold planning, retirement,
        # preemption) never read device arrays, so host bookkeeping for
        # the next round overlaps the in-flight chunk instead of
        # serializing on it
        self._slot_pos: List[int] = [0] * B
        self._slot_admit_seq: List[int] = [0] * B
        self._admit_seq = 0
        # rid -> (original prompt_len, tokens emitted before preemption,
        # prefix_hit so far): a preempted slot's progress, folded back
        # into its Completion when the requeued continuation retires
        self._preempted: Dict[int, Tuple[int, List[int], bool]] = {}
        # in-flight decode chunk (device futures) between dispatch() and
        # collect(); exactly one chunk may be outstanding
        self._inflight: Optional[Tuple[Any, Any]] = None
        self.cancellations = 0
        self.expirations = 0
        self.preemptions = 0
        self.fold_rows_total = 0
        # sketched long-context KV bookkeeping (host mirrors of the
        # device fold_base): first live logical block per slot, and
        # whether the slot's request opted into folding
        self._slot_first_lblk: List[int] = [0] * B
        self._slot_use_sketch: List[bool] = [False] * B
        self._used_rows = 0
        self.peak_used_rows = 0
        self.decode_steps = 0
        self.completed: List[Completion] = []
        self._base_key = jax.random.PRNGKey(sv.seed)
        # observability (repro.obs.ServeObserver or None).  Every hook
        # site guards with ``if self.obs is not None`` and passes only
        # host-side values, so obs off costs one attribute check and
        # obs on adds no device syncs; ``_round_idx`` paces the opt-in
        # sketch-fidelity probe (see ``_probe_fidelity``).
        self.obs: Any = None
        self._round_idx = 0

        if self.is_kv:
            # no max_seq clamp: a block larger than max_seq just means one
            # partially-used block per slot, while clamping could
            # manufacture a size that breaks the divisibility contract
            self.block_size = max(1, sv.kv_block_size)
            assert sv.prefix_block % self.block_size == 0, (
                f"kv_block_size {self.block_size} must divide prefix_block "
                f"{sv.prefix_block} so cached prefixes share whole blocks")
            # a spec engine's verify/draft writes overhang the committed
            # sequence by up to spec_max rows — every slot (even ones
            # decoding plainly: the verify step is batch-wide) reserves
            # them so overhang writes land in its own blocks, not drop
            self.spec_overhang = self.spec_max
            self.blocks_per_slot = -(-(sv.max_seq + self.spec_overhang)
                                     // self.block_size)
            nb = sv.num_kv_blocks or B * self.blocks_per_slot
            self.num_blocks = nb
            cache = tf.init_paged_cache(cfg, nb, self.block_size)
            if self.draft is not None:
                # the draft's shallow pool mirrors the target pool block
                # for block (same ids, same tables, same refcounts), so
                # prefix sharing, CoW forks and frees cover both for free
                cache = dict(cache)
                cache["draft"] = tf.init_paged_cache(
                    self.draft.cfg, nb, self.block_size)
            # block_bytes derive from the POOL leaves only — the FCS tail
            # tables added below are per-slot constant state, not paged
            pool_bytes = sum(int(a.size) * int(a.dtype.itemsize)
                             for a in jax.tree.leaves(cache))
            self.alloc = BlockAllocator(nb, pool_bytes // nb)
            self.prefix_cache = SketchPrefixCache(
                sv, allocator=self.alloc, block_size=self.block_size)
            tables0 = jnp.full((B, self.blocks_per_slot), nb, jnp.int32)
            self.sketch_on = bool(sv.kv_sketch_window)
            if self.sketch_on:
                W = int(sv.kv_sketch_window)
                bs = self.block_size
                assert W % bs == 0 and W >= bs, (
                    f"kv_sketch_window {W} must be a positive multiple of "
                    f"kv_block_size {bs}")
                self.kv_window = W
                Z = max(1, int(sv.kv_sketch_rows))
                ratio = max(1, int(sv.kv_sketch_ratio))
                T = kvs.pos_domain(sv.max_seq, bs)
                C = kvs.tail_cols(sv.max_seq, ratio)
                self.tail_rows, self.tail_cols, self.tail_domain = Z, C, T
                self.tail_coeffs = kvs.tail_coeffs(sv)
                self.tail_onehot = kvs.pos_onehot(self.tail_coeffs, T, C)
                # max committed-position advance per decode chunk; the
                # in-chunk fold cap keeps pace with it (+1 block of slack
                # so a lagging slot catches up instead of drifting)
                self.adv_max = sv.decode_chunk * (self.spec_max + 1)
                self.fold_cap = bs * (-(-self.adv_max // bs) + 1)
                bucket = max(1, min(sv.prefill_bucket, sv.max_seq))
                self.prefill_fold_cap = bs * (bucket // bs + 1)
                cache = dict(cache)
                cache["tail"] = kvs.init_tail(cfg, B, Z, C)
                if self.draft is not None:
                    cache["draft"] = dict(cache["draft"])
                    cache["draft"]["tail"] = kvs.init_tail(
                        self.draft.cfg, B, Z, C)
        else:
            # prefix reuse / paging are KV-cache concepts; a recurrent
            # scheduler gets neither (and misuse fails loudly on None)
            self.block_size = 0
            self.blocks_per_slot = 0
            self.num_blocks = 0
            self.spec_overhang = 0
            self.alloc = None
            self.prefix_cache = None
            self.sketch_on = False    # recurrent state never pages or folds
            cache = tf.init_cache(cfg, B, sv.max_seq)
            tables0 = jnp.zeros((B, 0), jnp.int32)

        self._state = DecodeState(
            cache=cache,
            tables=tables0,
            cur=jnp.zeros((B, 1), jnp.int32),
            pos=jnp.zeros((B,), jnp.int32),
            remaining=jnp.zeros((B,), jnp.int32),
            temp=jnp.zeros((B,), jnp.float32),
            top_k=jnp.zeros((B,), jnp.int32),
            keys=jnp.zeros((B, 2), jnp.uint32),
            spec_k=jnp.zeros((B,), jnp.int32),
            fold_base=jnp.zeros((B,), jnp.int32),
        )
        if self.spec_max > 0:
            self._chunk_fn = jax.jit(self._make_spec_chunk(),
                                     donate_argnums=(2,))
        else:
            self._chunk_fn = jax.jit(self._make_chunk(),
                                     donate_argnums=(1,))
        if self.is_kv:
            if self.sketch_on:
                # every slot of a sketch engine prefills through the
                # sketched chunk (fold_base == 0 reproduces the exact
                # graph bitwise), so prefill still compiles exactly once
                self._prefill_chunk = self._make_sketch_prefill(cfg, False)
                if self.draft is not None:
                    self._draft_prefill_chunk = self._make_sketch_prefill(
                        self.draft.cfg, True)
                self._fold_fn = jax.jit(self._make_fold(),
                                        donate_argnums=(0,))
                self._zero_tail = jax.jit(self._make_zero_tail(),
                                          donate_argnums=(0,))
                # opt-in fidelity probe (observability): jitted once,
                # invoked only at the collect() boundary at the
                # observer's cadence — never inside the decode chunk
                self._spread_fn = jax.jit(kvs.tail_row_spread)
            else:
                self._prefill_chunk = jax.jit(
                    functools.partial(tf.prefill_chunk, cfg=cfg,
                                      kernels=self.use_kernels),
                    donate_argnums=(1,))
                if self.draft is not None:
                    self._draft_prefill_chunk = jax.jit(
                        functools.partial(tf.prefill_chunk,
                                          cfg=self.draft.cfg,
                                          kernels=self.use_kernels),
                        donate_argnums=(1,))
            # copy-on-write block fork: copy one physical block's rows
            # (target AND draft pools) to a fresh block, device-side
            self._copy_block = jax.jit(
                lambda c, src, dst: jax.tree.map(
                    lambda a: a.at[:, dst].set(a[:, src]), c),
                donate_argnums=(0,))
        else:
            self._insert_fn = jax.jit(self._insert_state,
                                      donate_argnums=(0,))
            self._prefill = jax.jit(functools.partial(tf.prefill, cfg=cfg))
            # slot "reset" block: zero state inserted before (or instead
            # of, for 1-token prompts) the prefilled state
            self._zero_block = tf.init_cache(cfg, 1, sv.max_seq)
        if obs is not None:
            self.set_observer(obs)

    def set_observer(self, obs: Any) -> None:
        """Attach (or detach, with None) a ``repro.obs.ServeObserver``:
        the scheduler and its prefix cache report into it from every
        pump phase.  Safe to call at any pump boundary."""
        self.obs = obs
        if self.prefix_cache is not None:
            self.prefix_cache.obs = obs

    # ------------------------------------------------------------------
    # Compiled pieces
    # ------------------------------------------------------------------

    @staticmethod
    def _make_sampler():
        def sample(key, lg, temp, top_k):
            """Per-slot next token: greedy when temp == 0, else top-k
            filtered temperature sampling with the slot's own key.  The
            whole filter/sort/categorical branch is skipped in-graph
            (lax.cond) when every slot is greedy, so greedy-only chunks
            pay pure argmax while mixed chunks share the compilation."""
            greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)

            def do_sample(args):
                key, lg = args
                V = lg.shape[-1]
                srt = jnp.sort(lg, axis=-1)[:, ::-1]
                kth = jnp.take_along_axis(
                    srt, jnp.clip(top_k - 1, 0, V - 1)[:, None],
                    axis=1)[:, 0]
                keep = (top_k <= 0)[:, None] | (lg >= kth[:, None])
                filt = jnp.where(keep, lg, -jnp.inf)
                scaled = filt / jnp.maximum(temp, 1e-6)[:, None]
                split = jax.vmap(jax.random.split)(key)      # (B, 2, 2)
                key, ks = split[:, 0], split[:, 1]
                sampled = jax.vmap(jax.random.categorical)(ks, scaled)
                return key, jnp.where(temp > 0.0,
                                      sampled.astype(jnp.int32), greedy)

            def do_greedy(args):
                key, _ = args
                return key, greedy

            return jax.lax.cond(jnp.any(temp > 0.0), do_sample, do_greedy,
                                (key, lg))

        return sample

    def _make_chunk(self):
        cfg = self.cfg
        chunk = self.serve.decode_chunk
        is_kv = self.is_kv
        sample = self._make_sampler()
        sketch_on = self.sketch_on
        kernels = self.use_kernels
        if sketch_on:
            onehot, coeffs = self.tail_onehot, self.tail_coeffs
            fold_cap = self.fold_cap

        def chunk_fn(params, state: DecodeState):
            temp, top_k = state.temp, state.top_k
            # block tables are fixed for the chunk (admission happens
            # between chunks on the host), so they ride outside the carry
            tables = state.tables if is_kv else None

            def step(carry, _):
                cache, cur, pos, remaining, keys = carry
                running = remaining > 0
                logits, cache = tf.decode_step(params, cache, cur, pos, cfg,
                                               tables=tables,
                                               kernels=kernels)
                lg = logits[:, :cfg.vocab_size].astype(jnp.float32)
                keys, nxt = sample(keys, lg, temp, top_k)
                nxt = nxt.astype(jnp.int32)
                pos = pos + running.astype(jnp.int32)
                remaining = remaining - running.astype(jnp.int32)
                return (cache, nxt[:, None], pos, remaining, keys), \
                    (nxt, running)

            carry = (state.cache, state.cur, state.pos, state.remaining,
                     state.keys)
            (cache, cur, pos, remaining, keys), (toks, emits) = \
                jax.lax.scan(step, carry, None, length=chunk)
            new_state = DecodeState(cache=cache, tables=state.tables,
                                    cur=cur, pos=pos, remaining=remaining,
                                    temp=temp, top_k=top_k, keys=keys,
                                    spec_k=state.spec_k,
                                    fold_base=state.fold_base)
            return new_state, toks, emits        # toks/emits: (chunk, B)

        def sketched_chunk_fn(params, state: DecodeState, fold_len):
            """Sketch-engine chunk: fold aged blocks into the FCS tails
            ONCE at chunk start (fold_len (B,) rows per slot, decided by
            the host from committed positions), then run the usual scan
            with two-span decode.  Folded positions sit strictly below
            the window every in-chunk query keeps exact, so folding
            before the steps is equivalent to folding between them —
            and it keeps the fold out of the scan body."""
            temp, top_k = state.temp, state.top_k
            tables = state.tables
            cache = state.cache
            tail = kvs.fold_pool(cache["kv"], cache["tail"], tables,
                                 state.fold_base, fold_len, coeffs,
                                 fold_cap)
            cache = {**cache, "tail": tail}
            fold_base = state.fold_base + fold_len
            sk = {"fold_base": fold_base, "onehot": onehot}

            def step(carry, _):
                cache, cur, pos, remaining, keys = carry
                running = remaining > 0
                logits, cache = tf.decode_step(params, cache, cur, pos, cfg,
                                               tables=tables, sketch=sk,
                                               kernels=kernels)
                lg = logits[:, :cfg.vocab_size].astype(jnp.float32)
                keys, nxt = sample(keys, lg, temp, top_k)
                nxt = nxt.astype(jnp.int32)
                pos = pos + running.astype(jnp.int32)
                remaining = remaining - running.astype(jnp.int32)
                return (cache, nxt[:, None], pos, remaining, keys), \
                    (nxt, running)

            carry = (cache, state.cur, state.pos, state.remaining,
                     state.keys)
            (cache, cur, pos, remaining, keys), (toks, emits) = \
                jax.lax.scan(step, carry, None, length=chunk)
            new_state = DecodeState(cache=cache, tables=state.tables,
                                    cur=cur, pos=pos, remaining=remaining,
                                    temp=temp, top_k=top_k, keys=keys,
                                    spec_k=state.spec_k,
                                    fold_base=fold_base)
            return new_state, toks, emits

        return sketched_chunk_fn if sketch_on else chunk_fn

    def _make_spec_chunk(self):
        """Speculative decode chunk (serve/speculative.py): rounds of
        draft-propose -> verify-all -> accept/rollback, ONE compilation
        for the engine's lifetime; mixed spec / non-spec / sampled slots
        share it."""
        sketch = None
        if self.sketch_on:
            sketch = {"onehot": self.tail_onehot,
                      "coeffs": self.tail_coeffs,
                      "fold_cap": self.fold_cap}
        return build_spec_chunk(self.cfg, self.draft.cfg,
                                self.serve.decode_chunk, self.spec_max,
                                self._make_sampler(), sketch=sketch,
                                kernels=self.use_kernels)

    def _make_sketch_prefill(self, model_cfg: ModelConfig, is_draft: bool):
        """Jitted sketched prefill chunk: the legacy chunk plus the
        slot's tail slice and fold offset, so prompts longer than the
        window attend their already-folded span.  slot / fold_base are
        traced — one compilation covers every slot and fold state; with
        fold_base == 0 the produced pool rows are bitwise the legacy
        chunk's (the two-span select picks the exact output and the KV
        scatter is untouched)."""
        onehot = self.tail_onehot
        kernels = self.use_kernels

        def spc(params, pool, tail_full, tok, table, start, slot,
                fold_base):
            tail = jax.tree.map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1),
                tail_full)
            sk = {"fold_base": fold_base[None], "onehot": onehot}
            nc = tf.prefill_chunk(params, {"kv": pool, "tail": tail}, tok,
                                  table, start, model_cfg, sketch=sk,
                                  kernels=kernels)
            return nc["kv"]

        return jax.jit(spc, donate_argnums=(1,))

    def _make_fold(self):
        """Jitted out-of-chunk fold (prefill fold-through): fold the next
        ``fold_len`` aged rows of ONE slot — target and draft pools alike
        — into its tail tables.  Separate from the decode chunk (and
        compiled once), because prefill folds happen between prefill
        chunks, before the slot ever decodes."""
        coeffs = self.tail_coeffs
        cap = self.prefill_fold_cap

        def fold_fn(cache, row, fold_from, fold_len, slot):
            ff, fl = fold_from[None], fold_len[None]       # (1,)

            def one(pool, tail_full):
                t1 = jax.tree.map(
                    lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1,
                                                           axis=1),
                    tail_full)
                t1 = kvs.fold_pool(pool, t1, row[None], ff, fl, coeffs,
                                   cap)
                return jax.tree.map(
                    lambda full, s: jax.lax.dynamic_update_slice_in_dim(
                        full, s, slot, axis=1), tail_full, t1)

            out = {**cache, "tail": one(cache["kv"], cache["tail"])}
            if "draft" in cache:
                d = cache["draft"]
                out["draft"] = {**d, "tail": one(d["kv"], d["tail"])}
            return out

        return fold_fn

    def _make_zero_tail(self):
        """Jitted per-slot tail reset (slot admission): a new occupant
        must never attend the previous request's folded content."""
        def zt(cache, slot):
            z = lambda t: jax.tree.map(
                lambda a: a.at[:, slot].set(0.0), t)
            out = {**cache, "tail": z(cache["tail"])}
            if "draft" in cache:
                out["draft"] = {**cache["draft"],
                                "tail": z(cache["draft"]["tail"])}
            return out

        return zt

    @staticmethod
    def _insert_state(cache, block, slot):
        """Write a per-request recurrent prefill block (leaves (X, 1, ...))
        into slot ``slot`` of the preallocated slot state (leaves
        (X, B, ...)): equal-shape leaves are replaced wholesale — the slot
        'reset' that makes any stale state from the slot's previous
        occupant unobservable."""
        def one(c, b):
            return jax.lax.dynamic_update_slice(
                c, b.astype(c.dtype), (0, slot) + (0,) * (c.ndim - 2))
        return jax.tree.map(one, cache, block)

    # ------------------------------------------------------------------
    # Scheduling
    # ------------------------------------------------------------------

    def submit(self, req: Request) -> None:
        sv = self.serve
        S = len(req.tokens)
        assert req.max_new >= 1, "requests must ask for at least one token"
        assert S >= 1, "empty prompt"
        # the last write lands at position S - 1 + max_new
        assert S + req.max_new <= sv.max_seq, (
            f"prompt {S} + max_new {req.max_new} exceeds max_seq "
            f"{sv.max_seq}")
        if self.is_kv:
            # reject up front what the pool can never serve — otherwise
            # the impossible request head-of-line-blocks the FIFO queue
            # and only fails once every in-flight slot has drained
            bs = self.block_size
            need = -(-(S + req.max_new + self.spec_overhang) // bs)
            if self.sketch_on and req.kv_sketch is not False:
                # a sketched request never holds its whole context: its
                # peak is the exact window + one prefill bucket of write
                # frontier + one chunk of decode lookahead
                bucket = max(1, min(sv.prefill_bucket, sv.max_seq))
                peak = (self.kv_window // bs + -(-bucket // bs)
                        + -(-(self.adv_max + self.spec_overhang) // bs)
                        + 2)
                need = min(need, peak)
            assert need <= self.num_blocks, (
                f"request needs {need} KV blocks of {bs}, "
                f"pool has {self.num_blocks} (raise "
                f"cfg.serve.num_kv_blocks)")
        self._enqueue(req, front=False)
        if self.obs is not None:
            self.obs.request_queued(req.rid, S, req.priority)

    def _enqueue(self, req: Request, front: bool) -> None:
        """Priority-ordered queue insertion (descending priority, stable
        FIFO within a band — default priority 0 is a plain FIFO).
        ``front`` inserts at the HEAD of the request's priority band:
        used for preempted continuations, which are the oldest work in
        their band and must not lose their turn to later arrivals."""
        pr = req.priority
        if front:
            i = 0
            while i < len(self._queue) and self._queue[i].priority > pr:
                i += 1
        else:
            i = len(self._queue)
            while i > 0 and self._queue[i - 1].priority < pr:
                i -= 1
        self._queue.insert(i, req)

    def reseed(self, key: jax.Array) -> None:
        """Replace the base sampling key: per-slot keys for requests
        without an explicit seed derive from it (folded with the rid).
        Only NOT-YET-ADMITTED requests are affected — in-flight slots
        keep the keys they were admitted with (per-slot keys are engine
        state, resolved once at admission)."""
        self._base_key = key

    def _request_key(self, req: Request) -> jax.Array:
        if req.key is not None:
            return req.key
        if req.seed is not None:
            return jax.random.PRNGKey(req.seed)
        return jax.random.fold_in(self._base_key, req.rid)

    def _prefill_one(self, cache, tok: jax.Array, table: jax.Array,
                     off: int, slot: int, fold_base: int):
        """One prefill chunk through the target (and lockstep draft)
        pool.  In a sketch engine the sketched chunk is used for EVERY
        slot — with fold_base == 0 it writes bitwise the legacy rows —
        so prefill keeps compiling exactly once per engine."""
        if self.sketch_on:
            kv = self._prefill_chunk(self.params, cache["kv"],
                                     cache["tail"], tok, table,
                                     jnp.int32(off), jnp.int32(slot),
                                     jnp.int32(fold_base))
            cache = {**cache, "kv": kv}
            if self.draft is not None:
                dkv = self._draft_prefill_chunk(
                    self.draft.params, cache["draft"]["kv"],
                    cache["draft"]["tail"], tok, table, jnp.int32(off),
                    jnp.int32(slot), jnp.int32(fold_base))
                cache = {**cache, "draft": {**cache["draft"], "kv": dkv}}
            return cache
        kv = self._prefill_chunk(self.params, {"kv": cache["kv"]},
                                 tok, table, jnp.int32(off))
        cache = {**cache, "kv": kv["kv"]}
        if self.draft is not None:
            # the draft pool prefills in lockstep through the same
            # table, so cached-prefix blocks hold BOTH models' rows
            dkv = self._draft_prefill_chunk(
                self.draft.params, cache["draft"], tok, table,
                jnp.int32(off))
            cache = {**cache, "draft": dkv}
        return cache

    def _chunk_prefill_loop(self, cache, prompt: np.ndarray,
                            table: jax.Array, start_off: int,
                            slot: int = 0):
        """Feed prompt rows [start_off, S) through bucket-sized prefill
        chunks.  Starts are ALWAYS absolute bucket multiples — no tail
        clamp — so the chunk boundaries (and hence the cache rows) are
        identical whether the loop starts at 0 (cold miss) or at a cached-
        prefix boundary (hit), for any max_seq; overlap rows recompute to
        the values they already hold, and tail rows mapping past the
        request's reserved blocks are dropped by the paged scatter."""
        sv = self.serve
        S = len(prompt)
        if start_off >= S:
            return cache
        bucket = max(1, min(sv.prefill_bucket, sv.max_seq))
        off = (start_off // bucket) * bucket
        while off < S:
            seg = prompt[off:off + bucket]
            tok = np.zeros((1, bucket), np.int32)
            tok[0, :len(seg)] = seg
            t0 = time.perf_counter()
            cache = self._prefill_one(cache, jnp.asarray(tok), table, off,
                                      slot, 0)
            if self.obs is not None:
                self.obs.prefill_span(slot, off, len(seg),
                                      time.perf_counter() - t0)
            off += bucket
        return cache

    def _sketch_prefill_admit(self, slot: int, cache, prompt: np.ndarray,
                              shared: List[int], start_off: int):
        """Fold-through chunked prefill for a SKETCHED request: blocks
        allocate lazily just ahead of the write frontier, and blocks that
        age fully past the exact window fold into the slot's tail tables
        and return to the pool — a prompt's peak block hold is the window
        plus one prefill bucket, independent of its length.

        Returns (cache, slot_ids, first_lblk, ok); ``slot_ids`` are the
        blocks still held (logical blocks [first_lblk, ...)), already
        unreffed on failure (ok False -> caller defers the admission).
        """
        sv = self.serve
        bs = self.block_size
        W = self.kv_window
        S = len(prompt)
        NB = self.num_blocks
        bucket = max(1, min(sv.prefill_bucket, sv.max_seq))
        row = np.full((self.blocks_per_slot,), NB, np.int32)
        slot_ids = list(shared)
        row[:len(slot_ids)] = slot_ids
        first_lblk = 0
        fold_base = 0
        off = (start_off // bucket) * bucket
        while off < S:
            seg = prompt[off:off + bucket]
            end = off + len(seg)                   # prompt rows fed so far
            need_end = (end - 1) // bs             # last logical block hit
            have_end = first_lblk + len(slot_ids) - 1
            if need_end > have_end:
                ids = self._take_blocks(need_end - have_end)
                if ids is None:
                    self.alloc.unref(slot_ids)
                    return cache, [], 0, False
                row[have_end + 1:need_end + 1] = ids
                slot_ids.extend(ids)
            tok = np.zeros((1, bucket), np.int32)
            tok[0, :len(seg)] = seg
            t0 = time.perf_counter()
            cache = self._prefill_one(cache, jnp.asarray(tok),
                                      jnp.asarray(row), off, slot,
                                      fold_base)
            if self.obs is not None:
                self.obs.prefill_span(slot, off, len(seg),
                                      time.perf_counter() - t0)
            # fold whole blocks that aged past the window ([0, end) keeps
            # >= W exact rows; the decode resume row S-1 always stays
            # exact because fold_base <= S - W <= S - 1)
            n_elig = max(0, (end - W) // bs) - first_lblk
            while n_elig > 0:
                k = min(n_elig, self.prefill_fold_cap // bs)
                cache = self._fold_fn(cache, jnp.asarray(row),
                                      jnp.int32(fold_base),
                                      jnp.int32(k * bs), jnp.int32(slot))
                # sentinel the folded entries BEFORE freeing: a freed
                # block may be re-allocated (e.g. as a CoW fork target)
                # while this row is still live
                row[first_lblk:first_lblk + k] = NB
                dead = slot_ids[:k]
                del slot_ids[:k]
                self.alloc.unref(dead)
                first_lblk += k
                fold_base += k * bs
                self.fold_rows_total += k * bs
                if self.obs is not None:
                    self.obs.fold(slot, k * bs)
                n_elig -= k
            off += bucket
        return cache, slot_ids, first_lblk, True

    def _take_blocks(self, n: int) -> Optional[List[int]]:
        """Allocate ``n`` pool blocks, evicting IDLE prefix-cache entries
        under pressure (evicting busy ones frees nothing — their blocks
        stay reserved by the referencing slots — so hot prefixes are
        never wiped for a transient spike); None when the pool genuinely
        can't serve it now."""
        ids = self.alloc.alloc(n)
        while ids is None and self.prefix_cache.evict_one(idle_only=True):
            ids = self.alloc.alloc(n)
        return ids

    def _ensure_exclusive(self, slot: int, slot_ids: List[int], cache,
                          first_write: int, first_lblk: int = 0):
        """Copy-on-write fork: make every block of ``slot`` that decode
        can write — logical blocks covering positions >= ``first_write``
        — exclusively held (refcount 1).  Shared blocks (prefix-cache
        entries / other slots referencing them) are forked: a fresh pool
        block is allocated (evicting idle cache entries under pressure),
        the rows are copied device-side in BOTH the target and draft
        pools, ``slot_ids`` is rebound in place and the shared block
        loses this slot's reference.  Returns (cache, ok); ok False when
        the pool can't supply a fork target right now (caller unwinds
        and defers the admission).  ``first_lblk`` is the logical block
        index of ``slot_ids[0]`` — nonzero for sketched slots whose
        leading blocks already folded into the tail and were freed."""
        bs = self.block_size
        for i in range(max(0, first_write // bs - first_lblk),
                       len(slot_ids)):
            b = slot_ids[i]
            nb = self.alloc.fork(b)
            while nb is None and self.prefix_cache.evict_one(
                    idle_only=True):
                nb = self.alloc.fork(b)
            if nb is None:
                return cache, False
            if nb != b:      # was shared: copy rows into the fresh block
                cache = self._copy_block(cache, jnp.int32(b),
                                         jnp.int32(nb))
                slot_ids[i] = nb
        return cache, True

    def _admit(self, slot: int, req: Request) -> bool:
        """Try to admit ``req`` into ``slot``; False when the block pool
        can't currently reserve the request's KV (the caller leaves the
        request queued until blocks free up)."""
        prompt = np.asarray(req.tokens, np.int32)
        S = len(prompt)
        st = self._state
        hit = None
        fold_rows = 0
        if self.is_kv:
            bs = self.block_size
            use_sketch = self.sketch_on and req.kv_sketch is not False
            if req.rid not in self._admit_memo:
                hit = self.prefix_cache.lookup(prompt)
                # hits feed the admission path too: a hot prompt that
                # keeps hitting a short cached prefix must still get its
                # longer qualifying prefix admitted eventually
                self._admit_memo[req.rid] = self.prefix_cache.observe(
                    prompt)
            else:
                # pool-pressure retry: the request was counted on its
                # first attempt — re-resolve the hit statelessly (the
                # entry may have been evicted or admitted meanwhile, so
                # stats reflect the first attempt while Completion.
                # prefix_hit reflects how the request was actually
                # served) and reuse the memoized admission decision
                hit = self.prefix_cache.peek(prompt)
            admit_plen = self._admit_memo[req.rid]
            shared: List[int] = []
            start_off = 0
            if hit is not None:
                plen, ids = hit
                shared = list(ids)
                start_off = plen
                # pin the shared blocks BEFORE any allocation below can
                # pressure the cache into evicting (and freeing) them
                self.alloc.ref(shared)
            if admit_plen is not None and admit_plen <= start_off:
                admit_plen = None    # nothing beyond what we already share
            first_lblk = 0
            if use_sketch:
                # fold-through prefill: the tail must be zeroed FIRST
                # (the slot lane may hold a retired occupant's sums)
                cache0 = self._zero_tail(st.cache, jnp.int32(slot))
                st = st._replace(cache=cache0)
                cache, slot_ids, first_lblk, ok_pf = \
                    self._sketch_prefill_admit(slot, cache0, prompt,
                                               shared, start_off)
                if not ok_pf:
                    # blocks already unreffed; the prefill chunks donated
                    # the old pool buffers, so the threaded cache must
                    # land back in engine state before deferring
                    self._state = st._replace(cache=cache)
                    if not any(r is not None for r in self._slot_req):
                        raise RuntimeError(
                            f"kv pool ({self.num_blocks} blocks of {bs}) "
                            f"too small for sketched prompt {S} with "
                            f"window {self.kv_window}")
                    return False
            else:
                n_total = -(-(S + req.max_new + self.spec_overhang) // bs)
                new_ids = self._take_blocks(n_total - len(shared))
                if new_ids is None:
                    if hit is not None:
                        self.alloc.unref(shared)
                    if not any(r is not None for r in self._slot_req):
                        raise RuntimeError(
                            f"kv pool ({self.num_blocks} blocks of {bs}) "
                            f"too small for prompt {S} + max_new "
                            f"{req.max_new}")
                    return False
                slot_ids = shared + new_ids
                row = np.full((self.blocks_per_slot,), self.num_blocks,
                              np.int32)
                row[:len(slot_ids)] = slot_ids
                table = jnp.asarray(row)
                st = st._replace(tables=st.tables.at[slot].set(table))
                cache = self._chunk_prefill_loop(st.cache, prompt, table,
                                                 start_off, slot)
            self._slot_blocks[slot] = slot_ids
            if admit_plen is not None and first_lblk > 0:
                # fold-through freed leading prompt blocks — the prefix's
                # block run no longer exists, and admitting the surviving
                # suffix would register freed (re-allocatable) block ids
                # as live cache entries
                admit_plen = None
            if admit_plen is not None:
                self.prefix_cache.admit(prompt, admit_plen,
                                        tuple(slot_ids[:admit_plen // bs]))
            # copy-on-write (speculative engines): fork any block the
            # slot's decode region [S-1, ...) reaches that is still
            # shared (prefix hit with plen == S, or the donation above).
            # Plain decode's only shared-block write is the idempotent
            # last-prompt-token rewrite, but a verify step writes draft
            # proposals that may be REJECTED — a speculating slot must
            # never write a block with refcount > 1.
            if self.spec_max:
                cache, ok = self._ensure_exclusive(slot, slot_ids, cache,
                                                   S - 1, first_lblk)
            else:
                ok = True
            if not ok:
                # pool exhausted mid-fork: unwind the slot's references
                # (the cache keeps any entry admitted above — its blocks
                # now hold valid prefix rows) and leave the request
                # queued; the memo records that admission already
                # happened so a retry won't re-count or re-admit
                self._state = st._replace(
                    cache=cache,
                    tables=st.tables.at[slot].set(
                        jnp.full((self.blocks_per_slot,), self.num_blocks,
                                 jnp.int32)))
                self.alloc.unref(slot_ids)
                self._slot_blocks[slot] = []
                self._slot_use_sketch[slot] = False
                self._slot_first_lblk[slot] = 0
                self._admit_memo[req.rid] = None
                return False
            row = np.full((self.blocks_per_slot,), self.num_blocks,
                          np.int32)
            row[first_lblk:first_lblk + len(slot_ids)] = slot_ids
            st = st._replace(tables=st.tables.at[slot].set(
                jnp.asarray(row)))
            fold_rows = first_lblk * bs
            self._slot_first_lblk[slot] = first_lblk
            self._slot_use_sketch[slot] = use_sketch
            # used-rows tracks DEMAND: every row a live request attends,
            # shared prefix rows counted per referencing request — so
            # demand exceeding reserved is the zero-copy sharing win
            # made visible, not an accounting error
            self._slot_rows[slot] = S + req.max_new
            self._used_rows += self._slot_rows[slot]
            self.peak_used_rows = max(self.peak_used_rows, self._used_rows)
            self._admit_memo.pop(req.rid, None)
        else:
            # recurrent: exact-length prefill of all but the last token
            # (decode applies it — a recurrent step is not idempotent, so
            # unlike KV rows the last token must be consumed exactly once)
            if S > 1:
                _, pre = self._prefill(
                    self.params, {"tokens": jnp.asarray(prompt[None, :-1])})
            else:
                pre = self._zero_block        # fresh state, reset only
            cache = self._insert_fn(st.cache, pre, jnp.int32(slot))
        temp = (self.temperature if req.temperature is None
                else float(req.temperature))
        eff_spec = 0
        if self.spec_max:
            eff_spec = (self.serve.spec_k if req.spec_k is None
                        else int(req.spec_k))
            eff_spec = max(0, min(eff_spec, self.spec_max))
        st = st._replace(
            cache=cache,
            cur=st.cur.at[slot, 0].set(int(prompt[S - 1])),
            pos=st.pos.at[slot].set(S - 1),
            remaining=st.remaining.at[slot].set(req.max_new),
            temp=st.temp.at[slot].set(temp),
            top_k=st.top_k.at[slot].set(int(req.top_k)),
            keys=st.keys.at[slot].set(self._request_key(req)),
            spec_k=st.spec_k.at[slot].set(eff_spec),
            fold_base=st.fold_base.at[slot].set(fold_rows),
        )
        self._state = st
        self._slot_req[slot] = req
        self._slot_out[slot] = []
        self._slot_hit[slot] = hit is not None
        # host mirror of the device position: decode resumes at S - 1 and
        # collect() advances the mirror by the emitted count per round
        # (the chunk advances pos by exactly the tokens it emits), so
        # fold planning / retirement never read device arrays
        self._slot_pos[slot] = S - 1
        self._slot_admit_seq[slot] = self._admit_seq
        self._admit_seq += 1
        # host-side mirror for acceptance accounting: sampled slots never
        # accept proposals in-graph, so they don't count as speculating
        self._slot_spec[slot] = eff_spec if temp == 0.0 else 0
        if self.obs is not None:
            self.obs.request_admitted(req.rid, slot, hit is not None)
        return True

    def _complete(self, slot: int, status: str) -> Completion:
        """Build the Completion for ``slot``'s occupant, folding in any
        output the request emitted before an earlier preemption (a
        preempted request is requeued as a continuation whose prompt is
        the original prompt + the tokens already served — its Completion
        reports the ORIGINAL prompt_len and the full output)."""
        req = self._slot_req[slot]
        out = list(self._slot_out[slot][:req.max_new])
        hit = self._slot_hit[slot]
        plen = len(req.tokens)
        stash = self._preempted.pop(req.rid, None)
        if stash is not None:
            plen, prior, hit0 = stash
            out = prior + out
            hit = hit or hit0
        return Completion(rid=req.rid, prompt_len=plen,
                          tokens=np.asarray(out, np.int32),
                          prefix_hit=hit, status=status)

    def _complete_queued(self, req: Request, status: str) -> Completion:
        """Completion for a request leaving the QUEUE (cancelled or
        expired before admission); a preempted continuation surfaces the
        tokens it emitted before eviction."""
        plen, prior, hit = self._preempted.pop(
            req.rid, (len(req.tokens), [], False))
        self._admit_memo.pop(req.rid, None)
        return Completion(rid=req.rid, prompt_len=plen,
                          tokens=np.asarray(prior, np.int32),
                          prefix_hit=hit, status=status)

    def _release_slot_state(self, freed: List[int],
                            deactivate: bool = False) -> None:
        """Release every slot in ``freed`` — device tables, pool blocks,
        host mirrors — shared by retirement, cancellation, expiry and
        preemption.  ``deactivate`` additionally zeroes the device
        ``remaining`` (mid-flight evictions; a naturally retired slot's
        budget already reached zero on device)."""
        if not freed:
            return
        if deactivate:
            self._state = self._state._replace(
                remaining=self._state.remaining.at[
                    np.asarray(freed)].set(0))
        if self.is_kv:
            # invalidate the slots' table rows BEFORE their blocks can
            # be freed/reused: an idle slot still executes the decode
            # write every step, and only the sentinel makes it a no-op
            # (one batched row-scatter, not one update per slot)
            tables = self._state.tables.at[np.asarray(freed)].set(
                self.num_blocks)
            self._state = self._state._replace(tables=tables)
            if self.sketch_on:
                # a leaving slot's fold frontier resets with it; the tail
                # sums themselves are zeroed lazily at the NEXT admission
                self._state = self._state._replace(
                    fold_base=self._state.fold_base.at[
                        np.asarray(freed)].set(0))
            for s in freed:
                self.alloc.unref(self._slot_blocks[s])
                self._slot_blocks[s] = []
                self._used_rows -= self._slot_rows[s]
                self._slot_rows[s] = 0
                self._slot_first_lblk[s] = 0
                self._slot_use_sketch[s] = False
        for s in freed:
            self._slot_req[s] = None
            self._slot_out[s] = []
            self._slot_spec[s] = 0
            self._slot_pos[s] = 0

    def _retire(self) -> List[Completion]:
        """Retire every slot whose token budget is spent.  Purely
        host-side: a slot is done exactly when its collected output
        reached ``max_new`` (the chunk clamps emission to the remaining
        budget, so this coincides with device ``remaining == 0``)."""
        done: List[Completion] = []
        freed: List[int] = []
        for s, req in enumerate(self._slot_req):
            if req is not None and len(self._slot_out[s]) >= req.max_new:
                done.append(self._complete(s, "ok"))
                freed.append(s)
        self._release_slot_state(freed)
        self.completed.extend(done)
        if self.obs is not None:
            for c in done:
                self.obs.request_finished(c.rid, c.status, len(c.tokens))
        return done

    def cancel(self, rid: int, status: str = "cancelled"
               ) -> Optional[Completion]:
        """Cancel a queued or in-flight request mid-stream: a queued
        request just leaves the queue; an admitted one is evicted — its
        table row sentineled, its pool blocks unreffed (target and draft
        pools share refcounts, so both free together) — and the slot is
        immediately admittable again.  Returns the Completion (partial
        ``tokens``, ``status`` as given) or None for an unknown rid.
        Must run at a pump boundary: never between dispatch() and
        collect()."""
        assert self._inflight is None, (
            "cancel() between dispatch() and collect()")
        comp = None
        for i, r in enumerate(self._queue):
            if r.rid == rid:
                self._queue.pop(i)
                comp = self._complete_queued(r, status)
                break
        if comp is None:
            for s, r in enumerate(self._slot_req):
                if r is not None and r.rid == rid:
                    comp = self._complete(s, status)
                    self._release_slot_state([s], deactivate=True)
                    break
        if comp is None:
            return None
        if status == "expired":
            self.expirations += 1
        else:
            self.cancellations += 1
        self.completed.append(comp)
        if self.obs is not None:
            self.obs.request_finished(comp.rid, comp.status,
                                      len(comp.tokens))
        return comp

    def expire_deadlines(self, now: Optional[float] = None
                         ) -> List[Completion]:
        """Expire every request whose deadline has passed: queued ones
        drop with empty output, in-flight ones retire with whatever
        tokens they have (status "expired" either way)."""
        if now is None:
            now = time.monotonic()
        late = [r.rid for r in self._queue
                if r.deadline is not None and r.deadline <= now]
        late += [r.rid for r in self._slot_req
                 if r is not None and r.deadline is not None
                 and r.deadline <= now]
        return [c for c in (self.cancel(rid, "expired") for rid in late)
                if c is not None]

    def preempt(self, slot: int) -> Request:
        """Evict a RUNNING slot and requeue its request as a
        continuation: prompt extended by the tokens already emitted,
        budget reduced by the same count, reinserted at the head of its
        priority band.  The request later completes "ok" with its
        original prompt_len and its full output — preemption changes
        when it runs, not what it returns.  Sampled slots carry their
        advanced per-slot PRNG key so the continuation keeps drawing
        from the same stream.  Must run at a pump boundary."""
        assert self._inflight is None, (
            "preempt() between dispatch() and collect()")
        req = self._slot_req[slot]
        assert req is not None, f"preempt of empty slot {slot}"
        out = list(self._slot_out[slot])
        plen0, prior, hit0 = self._preempted.get(
            req.rid, (len(req.tokens), [], False))
        self._preempted[req.rid] = (plen0, prior + out,
                                    hit0 or self._slot_hit[slot])
        temp = (self.temperature if req.temperature is None
                else float(req.temperature))
        cont = dataclasses.replace(
            req,
            tokens=np.concatenate([np.asarray(req.tokens, np.int32),
                                   np.asarray(out, np.int32)]),
            max_new=req.max_new - len(out),
            key=(jnp.asarray(self._state.keys[slot]) if temp > 0
                 else req.key))
        self._release_slot_state([slot], deactivate=True)
        self.preemptions += 1
        if self.obs is not None:
            self.obs.request_preempted(req.rid, slot, len(out))
        # the continuation must not re-feed the count-min tracker (its
        # prefix was counted at first admission): memo None keeps hit
        # lookups stateless and suppresses re-admission of the extended
        # prompt, while a cached prefix the original admission donated
        # still gives the continuation a zero-copy resume
        self._admit_memo[req.rid] = None
        self._enqueue(cont, front=True)
        return cont

    def _preempt_for(self, req: Request) -> Optional[int]:
        """Preemption policy for a full engine: evict the lowest-priority
        running slot STRICTLY below ``req``'s priority (ties broken
        toward the most recently admitted — least sunk work), returning
        the freed slot, or None when preemption is off / no slot
        qualifies (equal-priority traffic is never preempted, so plain
        FIFO streams keep their old head-of-line behaviour)."""
        if not self.serve.preemption:
            return None
        best = None
        for s, r in enumerate(self._slot_req):
            if r is None or r.priority >= req.priority:
                continue
            rank = (r.priority, -self._slot_admit_seq[s])
            if best is None or rank < best[0]:
                best = (rank, s)
        if best is None:
            return None
        self.preempt(best[1])
        return best[1]

    def _plan_folds(self) -> np.ndarray:
        """Pre-chunk bookkeeping for sketched slots: allocate the blocks
        the coming chunk can write (lazy lookahead — a sketched slot
        never reserves its whole context) and decide how many rows each
        slot folds into its tail at the chunk head.  Returns the per-slot
        fold length (rows, block multiples) passed into the compiled
        chunk; the matching host-side frees happen in ``_finish_folds``
        AFTER the chunk consumed the folded blocks.  Positions come from
        the HOST mirror (``_slot_pos``), so planning the next chunk never
        synchronizes on the previous one."""
        bs = self.block_size
        W = self.kv_window
        fold = np.zeros((self.serve.max_batch,), np.int32)
        tables = self._state.tables
        dirty = False
        for s, req in enumerate(self._slot_req):
            if req is None or not self._slot_use_sketch[s]:
                continue
            p = self._slot_pos[s]
            first = self._slot_first_lblk[s]
            held = self._slot_blocks[s]
            # the chunk writes rows up to p + adv_max (+ rejected
            # speculative writes); clamp to the request's own demand
            last = min(p + self.adv_max, self._slot_rows[s] - 1) \
                + self.spec_overhang
            need_end = min(last // bs, self.blocks_per_slot - 1)
            have_end = first + len(held) - 1
            if need_end > have_end:
                ids = self._take_blocks(need_end - have_end)
                if ids is None:
                    raise RuntimeError(
                        f"kv pool exhausted extending sketched slot {s} "
                        f"(pool {self.num_blocks} blocks of {bs}; raise "
                        f"cfg.serve.num_kv_blocks or shrink "
                        f"kv_sketch_window)")
                tables = tables.at[s, have_end + 1:need_end + 1].set(
                    jnp.asarray(np.asarray(ids, np.int32)))
                held.extend(ids)
                dirty = True
            # fold whole blocks aged past the exact window, at most one
            # chunk's worth (the compiled fold span is fold_cap rows)
            n = min(max(0, (p + 1 - W) // bs - first), self.fold_cap // bs,
                    len(held))
            fold[s] = n * bs
        if dirty:
            self._state = self._state._replace(tables=tables)
        return fold

    def _finish_folds(self, fold: np.ndarray) -> None:
        """Post-chunk half of a fold: the chunk already accumulated the
        folded rows into the tails and advanced ``fold_base``; here the
        blocks leave the slot — sentinel the table entries FIRST (a freed
        block can be re-allocated immediately), then drop the refs."""
        tables = self._state.tables
        dead: List[int] = []
        dirty = False
        for s in range(self.serve.max_batch):
            n = int(fold[s]) // self.block_size
            if n == 0:
                continue
            first = self._slot_first_lblk[s]
            tables = tables.at[s, first:first + n].set(self.num_blocks)
            dirty = True
            dead.extend(self._slot_blocks[s][:n])
            del self._slot_blocks[s][:n]
            self._slot_first_lblk[s] = first + n
            self.fold_rows_total += n * self.block_size
            if self.obs is not None:
                self.obs.fold(s, n * self.block_size)
        if dirty:
            # sentinel the rows BEFORE the unref makes the blocks
            # re-allocatable (nothing allocates between these two lines,
            # so no other slot's table can claim a stale-mapped block)
            self._state = self._state._replace(tables=tables)
            self.alloc.unref(dead)

    @property
    def pending(self) -> bool:
        """True while any request is queued or occupying a slot — the
        public drain condition (``while sched.pending: sched.step()``)."""
        return bool(self._queue) or any(
            r is not None for r in self._slot_req)

    @property
    def queue_len(self) -> int:
        """Requests waiting for admission (the backpressure signal the
        async front-end bounds against cfg.serve.queue_depth)."""
        return len(self._queue)

    def progress(self) -> Dict[int, List[int]]:
        """rid -> tokens emitted so far, for every request still queued
        or in flight (a preempted request's pre-eviction output counts).
        The async front-end reads this after each collect() to stream
        per-token deltas without touching scheduler internals."""
        out: Dict[int, List[int]] = {}
        for rid, (_, prior, _) in self._preempted.items():
            out[rid] = list(prior)
        for s, r in enumerate(self._slot_req):
            if r is not None:
                out[r.rid] = out.get(r.rid, []) + list(self._slot_out[s])
        for r in self._queue:
            out.setdefault(r.rid, [])
        return out

    # ------------------------------------------------------------------
    # Pump phases — the building blocks of one scheduler round.  The
    # synchronous ``step()`` composes them back-to-back; the async pump
    # (serve/frontend.py) interleaves them so host-side admission and
    # chunked prefill overlap the in-flight device chunk:
    #
    #   expire_deadlines / cancel   (pump boundary only)
    #   admit_pending               (before OR during the chunk)
    #   dispatch                    (launch the chunk; returns futures)
    #       ... more admit_pending: prefill ops enqueue AFTER the chunk
    #       in device-stream order, and the chunk read pre-admission
    #       state (an idle slot emits nothing and its sentinel table row
    #       drops the KV write), so overlapped admission is invisible to
    #       the in-flight chunk ...
    #   collect                     (materialize tokens; retire)
    # ------------------------------------------------------------------

    def admit_pending(self) -> int:
        """Admission phase: move queued requests into free slots until
        the queue empties, the engine fills, or the block pool can't
        serve the head request (it stays queued — FIFO order within a
        priority band is preserved, so pool pressure never starves the
        head).  A full engine may PREEMPT a strictly lower-priority slot
        for a high-priority head (``cfg.serve.preemption``).  Safe to
        call while a chunk is in flight.  Returns the admission count."""
        admitted = 0
        while self._queue:
            head = self._queue[0]
            slot = next((s for s, r in enumerate(self._slot_req)
                         if r is None), None)
            if slot is None:
                slot = self._preempt_for(head) \
                    if self._inflight is None else None
            if slot is None or not self._admit(slot, head):
                if slot is not None and self.obs is not None:
                    # a free slot existed but the pool couldn't serve
                    # the head request right now — a deferral stall
                    self.obs.admission_deferred(head.rid)
                break                # full / pool pressure: wait
            self._queue.pop(0)
            admitted += 1
        return admitted

    def dispatch(self) -> bool:
        """Decode phase, launch half: run one compiled decode chunk
        ASYNCHRONOUSLY — jax dispatch returns futures, so the host keeps
        working (admission, prefill, stream delivery) while the device
        crunches; ``collect()`` materializes the result.  Returns False
        when no slot is active (nothing to run)."""
        assert self._inflight is None, "one decode chunk may be in flight"
        if not any(r is not None for r in self._slot_req):
            return False
        fold_host = None
        if self.sketch_on:
            fold_host = self._plan_folds()
        if self.spec_max > 0:
            if self.sketch_on:
                self._state, toks, emits = self._chunk_fn(
                    self.params, self.draft.params, self._state,
                    jnp.asarray(fold_host))
            else:
                self._state, toks, emits = self._chunk_fn(
                    self.params, self.draft.params, self._state)
        elif self.sketch_on:
            self._state, toks, emits = self._chunk_fn(
                self.params, self._state, jnp.asarray(fold_host))
        else:
            self._state, toks, emits = self._chunk_fn(self.params,
                                                      self._state)
        if fold_host is not None:
            # the fold's host half runs at dispatch time: the table
            # sentinels enqueue AFTER the chunk in device-stream order,
            # and any re-allocation's prefill writes enqueue later still
            self._finish_folds(fold_host)
        self.decode_steps += self.serve.decode_chunk
        self._inflight = (toks, emits)
        return True

    def collect(self) -> List[Completion]:
        """Decode phase, collect half: materialize the in-flight chunk's
        tokens (this is the ONE host-device sync point of a round),
        account them to their slots, advance the host position mirrors,
        and retire every request whose budget is spent.  Slots admitted
        while the chunk was in flight emitted nothing (their ``remaining``
        was 0 when the chunk launched), so overlap never misattributes
        tokens."""
        assert self._inflight is not None, "collect() without dispatch()"
        toks, emits = self._inflight
        self._inflight = None
        toks = np.asarray(toks)
        emits = np.asarray(emits)
        if toks.ndim == 2:               # plain chunk: one token per step
            toks = toks[:, :, None]
            emits = emits[:, :, None]
        round_tokens = 0
        for t in range(toks.shape[0]):
            for s in range(toks.shape[1]):
                if self._slot_req[s] is None:
                    continue
                e = int(emits[t, s].sum())
                if e == 0:
                    continue
                self._slot_out[s].extend(
                    int(x) for x in toks[t, s][emits[t, s]])
                self._slot_pos[s] += e
                round_tokens += e
                # one verify round: slot proposed spec_k tokens and
                # e - 1 of them survived verification
                rr, pp, aa = spec_round_accounting(self._slot_spec[s], e)
                if rr:
                    self.spec_rounds += rr
                    self.spec_proposed += pp
                    self.spec_accepted += aa
                    if self.obs is not None:
                        self.obs.spec_round(self._slot_req[s].rid, pp, aa)
        done = self._retire()
        if self.obs is not None:
            self._round_idx += 1
            self.obs.chunk_collected(
                round_tokens, len(self._queue),
                sum(r is not None for r in self._slot_req))
            if (self.sketch_on and self.obs.fidelity_every > 0
                    and self._round_idx % self.obs.fidelity_every == 0):
                self._probe_fidelity()
            self.obs.maybe_flush(self.stats)
        return done

    def _probe_fidelity(self) -> None:
        """Opt-in sketch-fidelity probe: per-slot relative spread of the
        Z independent hash-row tail estimates (``kv_sketch.
        tail_row_spread``), emitted as a gauge for every slot with
        folded content.  Runs ONLY here — at the ``collect()`` boundary,
        where the round's host-device sync just happened and the tail
        tables are already materialized engine state — and only at the
        observer's ``fidelity_every`` cadence, so the compiled chunk and
        the sync discipline of the hot path are untouched."""
        sp = np.asarray(self._spread_fn(self._state.cache["tail"]))
        for s, req in enumerate(self._slot_req):
            if req is None or not self._slot_use_sketch[s]:
                continue
            folded = self._slot_first_lblk[s] * self.block_size
            if folded <= 0:
                continue
            self.obs.fidelity(s, req.rid, folded, float(sp[s]))

    def step(self) -> List[Completion]:
        """One SYNCHRONOUS scheduler round — the closed-batch
        composition of the pump phases: expire deadlines, admit into
        free slots, run one compiled decode chunk and immediately
        collect it.  Returns the requests completed this round."""
        self.expire_deadlines()
        self.admit_pending()
        if not self.dispatch():
            return []
        return self.collect()

    def drain(self) -> List[Completion]:
        """Step until every queued and in-flight request completed."""
        done: List[Completion] = []
        while self.pending:
            done.extend(self.step())
        return done

    def run(self, requests: Optional[List[Request]] = None
            ) -> List[Completion]:
        """Closed-batch convenience: submit ``requests`` (if given) and
        drain."""
        for r in requests or []:
            self.submit(r)
        return self.drain()

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------

    @property
    def decode_compilations(self) -> int:
        """Number of times the chunked decode step has been compiled —
        the engine's contract is that this is 1 for its whole lifetime,
        regardless of the request mix (lengths, families, sampling)."""
        return self._chunk_fn._cache_size()

    @property
    def prefill_compilations(self) -> int:
        """Attention families: 1 for the engine's lifetime (the chunked
        prefill step is offset-traced).  Recurrent families: one per
        distinct prompt length (exact-length prefill)."""
        if self.is_kv:
            return self._prefill_chunk._cache_size()
        return self._prefill._cache_size()

    @property
    def state(self) -> DecodeState:
        return self._state

    @property
    def acceptance_rate(self) -> float:
        """Fraction of draft proposals that survived verification, over
        every verify round run by speculating (greedy, spec_k > 0)
        slots.  0.0 when nothing speculated."""
        return self.spec_accepted / max(self.spec_proposed, 1)

    @property
    def mean_accepted_run(self) -> float:
        """Mean tokens emitted per verify round by speculating slots
        (accepted draft tokens + the verified correction/bonus token) —
        the per-round decode advance; 1.0 means speculation never helps,
        spec_k + 1 is the ceiling."""
        return ((self.spec_accepted + self.spec_rounds)
                / max(self.spec_rounds, 1))

    def kv_cache_bytes(self) -> int:
        """Total bytes of the slot cache (the whole pool for attention
        families, the stacked recurrent state otherwise)."""
        return sum(int(a.size) * int(a.dtype.itemsize)
                   for a in jax.tree.leaves(self._state.cache))

    def kv_reserved_bytes(self) -> int:
        """Bytes of pool blocks currently allocated (slots + prefix
        cache) — what the engine actually reserves, vs the dense
        max_batch * max_seq equivalent."""
        return self.alloc.reserved_bytes() if self.is_kv else \
            self.kv_cache_bytes()

    def kv_peak_reserved_bytes(self) -> int:
        """High-water mark of reserved pool bytes over the engine's
        lifetime (the honest paged analogue of the dense reservation)."""
        return self.alloc.peak_reserved_bytes() if self.is_kv else \
            self.kv_cache_bytes()

    def kv_peak_used_bytes(self) -> int:
        """High-water mark of the KV row DEMAND of concurrently live
        requests ((S + max_new) per active slot; rows of a shared prefix
        count once per referencing request).  Reserved minus demand,
        when positive, bounds internal fragmentation (< one block per
        slot) plus idle cached prefixes; demand ABOVE reserved is memory
        zero-copy prefix sharing deduplicated away."""
        if not self.is_kv:
            return self.kv_cache_bytes()
        row_bytes = self.alloc.block_bytes / self.block_size
        return int(row_bytes * self.peak_used_rows)

    def kv_dense_equiv_bytes(self) -> int:
        """Bytes the old dense (L, max_batch, max_seq, K, hd) slot cache
        would have reserved for the same engine geometry."""
        if not self.is_kv:
            return self.kv_cache_bytes()
        row_bytes = self.alloc.block_bytes / self.block_size
        return int(row_bytes * self.serve.max_seq * self.serve.max_batch)

    def kv_sketch_tail_bytes(self) -> int:
        """Bytes of the per-slot FCS tail tables (target + draft) — the
        FIXED cost that replaces unbounded exact-KV growth past the
        window.  0 when the engine runs without sketching."""
        if not (self.is_kv and self.sketch_on):
            return 0
        total = kvs.tail_state_bytes(self._state.cache["tail"])
        if self.draft is not None:
            total += kvs.tail_state_bytes(
                self._state.cache["draft"]["tail"])
        return total

    def kv_sketch_exact_bytes(self) -> int:
        """Bytes of pool blocks currently held by SKETCHED slots — the
        exact recent-window span of the two-span cache."""
        if not (self.is_kv and self.sketch_on):
            return 0
        bb = self.alloc.block_bytes
        return sum(len(self._slot_blocks[s]) * bb
                   for s in range(self.serve.max_batch)
                   if self._slot_use_sketch[s])

    def stats(self) -> EngineStats:
        """The unified observability snapshot (see ``EngineStats``)."""
        st = EngineStats(
            queue_depth=len(self._queue),
            active_slots=sum(r is not None for r in self._slot_req),
            max_batch=self.serve.max_batch,
            completed=len(self.completed),
            cancelled=self.cancellations,
            expired=self.expirations,
            preempted=self.preemptions,
            decode_steps=self.decode_steps,
            decode_compilations=self.decode_compilations,
            prefill_compilations=self.prefill_compilations,
            fold_rows=self.fold_rows_total,
            kv_sketch_tail_bytes=self.kv_sketch_tail_bytes(),
            spec_rounds=self.spec_rounds,
            spec_proposed=self.spec_proposed,
            spec_accepted=self.spec_accepted,
        )
        if self.is_kv:
            st.pool_blocks = self.num_blocks
            st.block_size = self.block_size
            st.blocks_reserved = self.alloc.reserved
            st.blocks_free = self.alloc.free_count
            st.blocks_peak = self.alloc.peak_reserved
            st.kv_reserved_bytes = self.kv_reserved_bytes()
            st.kv_peak_reserved_bytes = self.kv_peak_reserved_bytes()
            st.kv_peak_used_bytes = self.kv_peak_used_bytes()
            st.kv_dense_equiv_bytes = self.kv_dense_equiv_bytes()
            pc = self.prefix_cache.stats
            st.prefix_lookups = pc.lookups
            st.prefix_hits = pc.hits
            st.prefix_admitted = pc.admitted
            st.prefix_evicted = pc.evicted
            st.prefix_cached_bytes = pc.bytes
        return st
