"""Continuous-batching slot scheduler over fixed preallocated per-slot state.

The engine owns ``max_batch`` slots.  For attention families the slot state
is one (L, max_batch, max_seq, K, hd) KV cache; for recurrent families
(ssm / hybrid) it is the family's per-layer recurrent state stacked on the
same slot axis ((L, max_batch, ...) leaves, plus the hybrid shared-KV
rows).  Decode runs as ONE jitted function for the engine's lifetime: a
``jax.lax.scan`` of single-token steps over fixed shapes, with per-slot
position / active masks and per-slot sampling parameters doing the work
that used to require per-request shapes.  Requests of arbitrary (mixed)
prompt lengths, families and sampling settings are admitted into free
slots between chunks and retired when their token budget is spent; the
decode step therefore compiles exactly once per engine (see
``decode_compilations``).

Prefill:

  * attention families (dense / moe / audio / vlm) use CHUNKED prefill:
    the prompt is fed through ``tf.prefill_chunk`` in ``prefill_bucket``-
    sized chunks written straight into the slot KV cache, each chunk
    attending against everything below it.  Chunk starts are aligned to
    absolute multiples of the bucket, so a prefix-cache hit resuming at
    ``plen`` replays the same chunk boundaries a cold miss used — the two
    paths produce bitwise-identical cache rows (the overlap recompute is
    idempotent) and therefore identical tokens.  Slot and offset are
    traced, so prefill compiles exactly once too, for any prompt length.
  * recurrent families prefill the first S-1 prompt tokens exactly (no
    padding — trailing pad tokens would corrupt a recurrence) and insert
    the resulting state wholesale into the slot (the slot "reset"); the
    last prompt token is fed through the first decode step, which advances
    the state and samples the first output in-graph.  Prefill compiles per
    distinct prompt length, as the synchronized fallback always did.

Slot-uniform decode semantics (all shape-static):

  * every slot decodes every step; inactive slots mutate only their own
    state, which is harmless: KV rows at a position are always rewritten
    before any query attends there, and recurrent slot state is replaced
    wholesale at the next admit, so junk is never observed.
  * a freshly admitted attention-family request resumes at
    ``pos = S - 1`` by re-feeding its last prompt token: the recomputed KV
    row is bit-identical (it depends only on that token's residual stream)
    and the resulting logits sample the first output token in-graph —
    prefill logits never cross the host boundary.
  * sampling is per-slot: temperature / top-k / PRNG key live in (B,)
    engine state set at admission, so greedy and sampled requests (and
    different seeds) share the one compiled chunk.  A greedy slot's tokens
    are bitwise-independent of its neighbours.

Prefix reuse (attention families only — a recurrent state at a prefix
boundary is not recoverable from an end-of-prompt prefill) is gated by the
count-min admission filter in serve/prefix_cache.py.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig
from repro.models import transformer as tf
from repro.serve.prefix_cache import SketchPrefixCache

KV_FAMILIES = ("dense", "moe", "audio", "vlm")
RECURRENT_FAMILIES = ("ssm", "hybrid")


@dataclass
class Request:
    rid: int
    tokens: np.ndarray           # (S,) int32 prompt
    max_new: int
    # per-request sampling: None temperature falls back to the scheduler
    # default; top_k == 0 disables top-k filtering.  The slot PRNG key is
    # ``key`` when given, else PRNGKey(seed), else derived from the
    # scheduler's base key and the rid.
    temperature: Optional[float] = None
    top_k: int = 0
    seed: Optional[int] = None
    key: Optional[jax.Array] = None


@dataclass
class Completion:
    rid: int
    prompt_len: int
    tokens: np.ndarray           # (max_new,) int32 generated
    prefix_hit: bool


class DecodeState(NamedTuple):
    """All device-resident engine state (a pytree; see
    launch.shardings.serve_state_pspecs for its mesh placement)."""
    cache: Dict[str, Any]        # family slot state, leaves (L|G, B, ...)
    cur: jax.Array               # (B, 1) next token to feed per slot
    pos: jax.Array               # (B,)  write/attend position per slot
    remaining: jax.Array         # (B,)  output tokens still owed per slot
    temp: jax.Array              # (B,)  sampling temperature per slot
    top_k: jax.Array             # (B,)  top-k cutoff per slot (0 = off)
    keys: jax.Array              # (B, 2) per-slot sampling PRNG keys


class SlotScheduler:
    def __init__(self, cfg: ModelConfig, params: Any,
                 serve: Optional[ServeConfig] = None,
                 temperature: float = 0.0):
        if cfg.family not in KV_FAMILIES + RECURRENT_FAMILIES:
            raise ValueError(f"unknown family {cfg.family!r}")
        self.cfg = cfg
        self.params = params
        self.serve = serve if serve is not None else cfg.serve
        self.temperature = float(temperature)   # default for requests
        self.is_kv = cfg.family in KV_FAMILIES
        sv = self.serve
        B = sv.max_batch
        # prefix reuse is a KV-cache concept; a recurrent scheduler gets
        # no idle count-min table (and misuse fails loudly on None)
        self.prefix_cache = SketchPrefixCache(sv) if self.is_kv else None
        self._queue: List[Request] = []
        self._slot_req: List[Optional[Request]] = [None] * B
        self._slot_out: List[List[int]] = [[] for _ in range(B)]
        self._slot_hit: List[bool] = [False] * B
        self.decode_steps = 0
        self.completed: List[Completion] = []
        self._base_key = jax.random.PRNGKey(sv.seed)

        self._state = DecodeState(
            cache=tf.init_cache(cfg, B, sv.max_seq),
            cur=jnp.zeros((B, 1), jnp.int32),
            pos=jnp.zeros((B,), jnp.int32),
            remaining=jnp.zeros((B,), jnp.int32),
            temp=jnp.zeros((B,), jnp.float32),
            top_k=jnp.zeros((B,), jnp.int32),
            keys=jnp.zeros((B, 2), jnp.uint32),
        )
        self._chunk_fn = jax.jit(self._make_chunk(), donate_argnums=(1,))
        self._insert_fn = jax.jit(self._insert_state, donate_argnums=(0,))
        if self.is_kv:
            self._prefill_chunk = jax.jit(
                functools.partial(tf.prefill_chunk, cfg=cfg),
                donate_argnums=(1,))
        else:
            self._prefill = jax.jit(functools.partial(tf.prefill, cfg=cfg))
            # slot "reset" block: zero state inserted before (or instead
            # of, for 1-token prompts) the prefilled state
            self._zero_block = tf.init_cache(cfg, 1, sv.max_seq)

    # ------------------------------------------------------------------
    # Compiled pieces
    # ------------------------------------------------------------------

    def _make_chunk(self):
        cfg = self.cfg
        chunk = self.serve.decode_chunk

        def sample(key, lg, temp, top_k):
            """Per-slot next token: greedy when temp == 0, else top-k
            filtered temperature sampling with the slot's own key.  The
            whole filter/sort/categorical branch is skipped in-graph
            (lax.cond) when every slot is greedy, so greedy-only chunks
            pay pure argmax while mixed chunks share the compilation."""
            greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)

            def do_sample(args):
                key, lg = args
                V = lg.shape[-1]
                srt = jnp.sort(lg, axis=-1)[:, ::-1]
                kth = jnp.take_along_axis(
                    srt, jnp.clip(top_k - 1, 0, V - 1)[:, None],
                    axis=1)[:, 0]
                keep = (top_k <= 0)[:, None] | (lg >= kth[:, None])
                filt = jnp.where(keep, lg, -jnp.inf)
                scaled = filt / jnp.maximum(temp, 1e-6)[:, None]
                split = jax.vmap(jax.random.split)(key)      # (B, 2, 2)
                key, ks = split[:, 0], split[:, 1]
                sampled = jax.vmap(jax.random.categorical)(ks, scaled)
                return key, jnp.where(temp > 0.0,
                                      sampled.astype(jnp.int32), greedy)

            def do_greedy(args):
                key, _ = args
                return key, greedy

            return jax.lax.cond(jnp.any(temp > 0.0), do_sample, do_greedy,
                                (key, lg))

        def chunk_fn(params, state: DecodeState):
            temp, top_k = state.temp, state.top_k

            def step(carry, _):
                cache, cur, pos, remaining, keys = carry
                running = remaining > 0
                logits, cache = tf.decode_step(params, cache, cur, pos, cfg)
                lg = logits[:, :cfg.vocab_size].astype(jnp.float32)
                keys, nxt = sample(keys, lg, temp, top_k)
                nxt = nxt.astype(jnp.int32)
                pos = pos + running.astype(jnp.int32)
                remaining = remaining - running.astype(jnp.int32)
                return (cache, nxt[:, None], pos, remaining, keys), \
                    (nxt, running)

            carry = (state.cache, state.cur, state.pos, state.remaining,
                     state.keys)
            (cache, cur, pos, remaining, keys), (toks, emits) = \
                jax.lax.scan(step, carry, None, length=chunk)
            new_state = DecodeState(cache=cache, cur=cur, pos=pos,
                                    remaining=remaining, temp=temp,
                                    top_k=top_k, keys=keys)
            return new_state, toks, emits        # toks/emits: (chunk, B)

        return chunk_fn

    @staticmethod
    def _insert_state(cache, block, slot):
        """Write a per-request prefill block (leaves (X, 1, ...)) into slot
        ``slot`` of the preallocated slot state (leaves (X, B, ...)):
        KV-block leaves land at sequence offset 0, equal-shape recurrent
        leaves are replaced wholesale — the slot 'reset' that makes any
        stale state from the slot's previous occupant unobservable."""
        def one(c, b):
            return jax.lax.dynamic_update_slice(
                c, b.astype(c.dtype), (0, slot) + (0,) * (c.ndim - 2))
        return jax.tree.map(one, cache, block)

    # ------------------------------------------------------------------
    # Scheduling
    # ------------------------------------------------------------------

    def submit(self, req: Request) -> None:
        sv = self.serve
        S = len(req.tokens)
        assert req.max_new >= 1, "requests must ask for at least one token"
        assert S >= 1, "empty prompt"
        # the last write lands at position S - 1 + max_new
        assert S + req.max_new <= sv.max_seq, (
            f"prompt {S} + max_new {req.max_new} exceeds max_seq "
            f"{sv.max_seq}")
        self._queue.append(req)

    def reseed(self, key: jax.Array) -> None:
        """Replace the base sampling key: per-slot keys for requests
        without an explicit seed derive from it (folded with the rid)."""
        self._base_key = key

    def _request_key(self, req: Request) -> jax.Array:
        if req.key is not None:
            return req.key
        if req.seed is not None:
            return jax.random.PRNGKey(req.seed)
        return jax.random.fold_in(self._base_key, req.rid)

    def _chunk_prefill_loop(self, cache, prompt: np.ndarray, slot: int,
                            start_off: int):
        """Feed prompt rows [start_off, S) through bucket-sized prefill
        chunks.  Starts are aligned to absolute bucket multiples (and the
        tail chunk is clamped into [0, max_seq - bucket]), so the chunk
        boundaries — and hence the cache rows — are identical whether the
        loop starts at 0 (cold miss) or at a cached-prefix boundary (hit);
        overlap rows recompute to the same values they already hold."""
        sv = self.serve
        S = len(prompt)
        if start_off >= S:
            return cache
        bucket = max(1, min(sv.prefill_bucket, sv.max_seq))
        off = (start_off // bucket) * bucket
        while off < S:
            start = min(off, sv.max_seq - bucket)
            seg = prompt[start:start + bucket]
            tok = np.zeros((1, bucket), np.int32)
            tok[0, :len(seg)] = seg
            cache = self._prefill_chunk(self.params, cache,
                                        jnp.asarray(tok), jnp.int32(slot),
                                        jnp.int32(start))
            off += bucket
        return cache

    def _admit(self, slot: int, req: Request) -> None:
        prompt = np.asarray(req.tokens, np.int32)
        S = len(prompt)
        st = self._state
        hit = None
        if self.is_kv:
            hit = self.prefix_cache.lookup(prompt)
            admit_plen = None
            if hit is not None:
                plen, block_np = hit
                self.prefix_cache.touch(prompt)  # hits keep counts fresh
                block = jax.tree.map(jnp.asarray, block_np)
                cache = self._insert_fn(st.cache, {"kv": block},
                                        jnp.int32(slot))
                start_off = plen
            else:
                admit_plen = self.prefix_cache.observe(prompt)
                cache, start_off = st.cache, 0
            cache = self._chunk_prefill_loop(cache, prompt, slot, start_off)
            if admit_plen is not None:
                blk = jax.tree.map(
                    lambda a: np.asarray(a[:, slot:slot + 1, :admit_plen]),
                    cache["kv"])
                self.prefix_cache.admit(prompt, admit_plen, blk)
        else:
            # recurrent: exact-length prefill of all but the last token
            # (decode applies it — a recurrent step is not idempotent, so
            # unlike KV rows the last token must be consumed exactly once)
            if S > 1:
                _, pre = self._prefill(
                    self.params, {"tokens": jnp.asarray(prompt[None, :-1])})
            else:
                pre = self._zero_block        # fresh state, reset only
            cache = self._insert_fn(st.cache, pre, jnp.int32(slot))
        temp = (self.temperature if req.temperature is None
                else float(req.temperature))
        st = st._replace(
            cache=cache,
            cur=st.cur.at[slot, 0].set(int(prompt[S - 1])),
            pos=st.pos.at[slot].set(S - 1),
            remaining=st.remaining.at[slot].set(req.max_new),
            temp=st.temp.at[slot].set(temp),
            top_k=st.top_k.at[slot].set(int(req.top_k)),
            keys=st.keys.at[slot].set(self._request_key(req)),
        )
        self._state = st
        self._slot_req[slot] = req
        self._slot_out[slot] = []
        self._slot_hit[slot] = hit is not None

    def _retire(self) -> List[Completion]:
        done: List[Completion] = []
        remaining = np.asarray(self._state.remaining)
        for s, req in enumerate(self._slot_req):
            if req is not None and remaining[s] == 0:
                done.append(Completion(
                    rid=req.rid, prompt_len=len(req.tokens),
                    tokens=np.asarray(self._slot_out[s][:req.max_new],
                                      np.int32),
                    prefix_hit=self._slot_hit[s]))
                self._slot_req[s] = None
                self._slot_out[s] = []
        self.completed.extend(done)
        return done

    @property
    def pending(self) -> bool:
        """True while any request is queued or occupying a slot — the
        public drain condition (``while sched.pending: sched.step()``)."""
        return bool(self._queue) or any(
            r is not None for r in self._slot_req)

    def step(self) -> List[Completion]:
        """One scheduler round: admit queued requests into free slots, run
        one compiled decode chunk, collect emitted tokens, retire finished
        requests.  Returns the requests completed this round."""
        for s in range(self.serve.max_batch):
            if self._slot_req[s] is None and self._queue:
                self._admit(s, self._queue.pop(0))
        if not any(r is not None for r in self._slot_req):
            return []
        self._state, toks, emits = self._chunk_fn(self.params, self._state)
        self.decode_steps += self.serve.decode_chunk
        toks = np.asarray(toks)
        emits = np.asarray(emits)
        for t in range(toks.shape[0]):
            for s in range(toks.shape[1]):
                if emits[t, s] and self._slot_req[s] is not None:
                    self._slot_out[s].append(int(toks[t, s]))
        return self._retire()

    def run(self, requests: Optional[List[Request]] = None
            ) -> List[Completion]:
        """Drain: submit ``requests`` (if given) and step until every
        queued and in-flight request has completed."""
        for r in requests or []:
            self.submit(r)
        done: List[Completion] = []
        while self.pending:
            done.extend(self.step())
        return done

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------

    @property
    def decode_compilations(self) -> int:
        """Number of times the chunked decode step has been compiled —
        the engine's contract is that this is 1 for its whole lifetime,
        regardless of the request mix (lengths, families, sampling)."""
        return self._chunk_fn._cache_size()

    @property
    def prefill_compilations(self) -> int:
        """Attention families: 1 for the engine's lifetime (the chunked
        prefill step is offset-traced).  Recurrent families: one per
        distinct prompt length (exact-length prefill)."""
        if self.is_kv:
            return self._prefill_chunk._cache_size()
        return self._prefill._cache_size()

    @property
    def state(self) -> DecodeState:
        return self._state

    def kv_cache_bytes(self) -> int:
        return sum(int(a.size) * int(a.dtype.itemsize)
                   for a in jax.tree.leaves(self._state.cache))
