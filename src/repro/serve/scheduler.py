"""Continuous-batching slot scheduler over a fixed preallocated KV cache.

The engine owns ``max_batch`` slots backed by one (L, max_batch, max_seq,
K, hd) KV cache allocated up front — no cache regrowth, ever.  Decode runs
as ONE jitted function for the engine's lifetime: a ``jax.lax.scan`` of
``decode_chunk`` single-token steps over fixed shapes, with per-slot
position / active / forced masks doing the work that used to require
per-request shapes.  Requests of arbitrary (mixed) prompt lengths are
admitted into free slots between chunks and retired when their token budget
is spent; the decode step therefore compiles exactly once per engine (see
``decode_compilations``), while prefill compiles once per prompt-length
bucket (``cfg.serve.prefill_bucket``).

Slot-uniform decode semantics (all shape-static):

  * every slot decodes every step; inactive slots re-write their own stale
    KV row, which is harmless: a row at position p is always (re)written
    before any query attends to p (the mask allows positions <= pos, and
    pos advances only after the write), so junk is never observed.
  * a freshly admitted request resumes at ``pos = prefill_len - 1`` by
    re-feeding its last prompt token: the recomputed KV row is identical
    (it depends only on that token's residual stream) and the resulting
    logits sample the first output token in-graph — prefill logits never
    cross the host boundary.
  * prompt tokens not covered by a prefix-cache hit are *forced*: the
    per-slot forced queue overrides sampling and suppresses emission until
    exhausted, which is how a cached prefix + uncached suffix runs through
    the same compiled decode step.

Prefix reuse is gated by the count-min admission filter in
serve/prefix_cache.py.  Supported families: those with a (L, B, S, K, hd)
"kv" cache (dense / moe / audio / vlm); recurrent-state families are
served by the synchronized fallback in serve/engine.py.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig
from repro.models import transformer as tf
from repro.serve.prefix_cache import SketchPrefixCache

KV_FAMILIES = ("dense", "moe", "audio", "vlm")


@dataclass
class Request:
    rid: int
    tokens: np.ndarray           # (S,) int32 prompt
    max_new: int


@dataclass
class Completion:
    rid: int
    prompt_len: int
    tokens: np.ndarray           # (max_new,) int32 generated
    prefix_hit: bool


class DecodeState(NamedTuple):
    """All device-resident engine state (a pytree; see
    launch.shardings.serve_state_pspecs for its mesh placement)."""
    cache: Dict[str, Any]        # {"kv": {"k": (L,B,Smax,K,hd), "v": ...}}
    cur: jax.Array               # (B, 1) next token to feed per slot
    pos: jax.Array               # (B,)  write/attend position per slot
    remaining: jax.Array         # (B,)  output tokens still owed per slot
    forced: jax.Array            # (B, F) teacher-forced prompt suffixes
    forced_n: jax.Array          # (B,)  forced-queue length per slot
    forced_i: jax.Array          # (B,)  forced-queue cursor per slot
    key: jax.Array               # (2,)  sampling PRNG key


def _bucket(n: int, bucket: int) -> int:
    return -(-n // bucket) * bucket


class SlotScheduler:
    def __init__(self, cfg: ModelConfig, params: Any,
                 serve: Optional[ServeConfig] = None,
                 temperature: float = 0.0):
        if cfg.family not in KV_FAMILIES:
            raise ValueError(
                f"SlotScheduler needs a kv cache family, got {cfg.family!r}")
        self.cfg = cfg
        self.params = params
        self.serve = serve if serve is not None else cfg.serve
        self.temperature = float(temperature)
        sv = self.serve
        B = sv.max_batch
        # cap on the uncached suffix a prefix hit may leave (it is
        # forced-decoded one token per step) and on the forced-queue
        # width; decoupled from prefill padding so prefill_bucket=1
        # (exact-length prefill, e.g. for moe) keeps hits possible.
        self.max_suffix = max(sv.prefill_bucket, sv.prefix_block)
        self.prefix_cache = SketchPrefixCache(sv)
        self._queue: List[Request] = []
        self._slot_req: List[Optional[Request]] = [None] * B
        self._slot_out: List[List[int]] = [[] for _ in range(B)]
        self._slot_hit: List[bool] = [False] * B
        self.decode_steps = 0
        self.completed: List[Completion] = []

        self._state = DecodeState(
            cache=tf.init_cache(cfg, B, sv.max_seq),
            cur=jnp.zeros((B, 1), jnp.int32),
            pos=jnp.zeros((B,), jnp.int32),
            remaining=jnp.zeros((B,), jnp.int32),
            forced=jnp.zeros((B, self.max_suffix), jnp.int32),
            forced_n=jnp.zeros((B,), jnp.int32),
            forced_i=jnp.zeros((B,), jnp.int32),
            key=jax.random.PRNGKey(sv.seed),
        )
        self._chunk_fn = jax.jit(self._make_chunk(), donate_argnums=(1,))
        self._prefill = jax.jit(functools.partial(tf.prefill, cfg=cfg))
        self._insert_fn = jax.jit(self._insert_kv, donate_argnums=(0,))

    # ------------------------------------------------------------------
    # Compiled pieces
    # ------------------------------------------------------------------

    def _make_chunk(self):
        cfg = self.cfg
        temp = self.temperature
        chunk = self.serve.decode_chunk

        def chunk_fn(params, state: DecodeState):
            forced, forced_n = state.forced, state.forced_n

            def step(carry, _):
                cache, cur, pos, remaining, forced_i, key = carry
                is_forced = forced_i < forced_n
                running = (remaining > 0) | is_forced
                logits, cache = tf.decode_step(params, cache, cur, pos, cfg)
                lg = logits[:, :cfg.vocab_size]
                if temp > 0.0:
                    key, k = jax.random.split(key)
                    sampled = jax.random.categorical(k, lg / temp, axis=-1)
                else:
                    sampled = jnp.argmax(lg, axis=-1)
                sampled = sampled.astype(jnp.int32)
                ftok = jnp.take_along_axis(
                    forced,
                    jnp.clip(forced_i, 0, forced.shape[1] - 1)[:, None],
                    axis=1)[:, 0]
                nxt = jnp.where(is_forced, ftok, sampled)
                emit = running & ~is_forced
                pos = pos + running.astype(jnp.int32)
                remaining = remaining - emit.astype(jnp.int32)
                forced_i = forced_i + is_forced.astype(jnp.int32)
                return (cache, nxt[:, None], pos, remaining, forced_i, key), \
                    (nxt, emit)

            carry = (state.cache, state.cur, state.pos, state.remaining,
                     state.forced_i, state.key)
            (cache, cur, pos, remaining, forced_i, key), (toks, emits) = \
                jax.lax.scan(step, carry, None, length=chunk)
            new_state = DecodeState(cache=cache, cur=cur, pos=pos,
                                    remaining=remaining, forced=forced,
                                    forced_n=forced_n, forced_i=forced_i,
                                    key=key)
            return new_state, toks, emits        # toks/emits: (chunk, B)

        return chunk_fn

    @staticmethod
    def _insert_kv(cache, block, slot):
        """Write a prefill KV block ({"k","v"} leaves (L, 1, S_b, K, hd))
        into slot ``slot`` of the full cache at positions [0, S_b)."""
        def one(c, b):
            return jax.lax.dynamic_update_slice(
                c, b.astype(c.dtype), (0, slot, 0, 0, 0))
        return {**cache, "kv": jax.tree.map(one, cache["kv"], block)}

    # ------------------------------------------------------------------
    # Scheduling
    # ------------------------------------------------------------------

    def submit(self, req: Request) -> None:
        sv = self.serve
        S = len(req.tokens)
        assert req.max_new >= 1, "requests must ask for at least one token"
        assert S >= 1, "empty prompt"
        # the last write lands at position S - 1 + max_new (bucketed
        # prefill is capped at max_seq in _admit)
        assert S + req.max_new <= sv.max_seq, (
            f"prompt {S} + max_new {req.max_new} exceeds max_seq "
            f"{sv.max_seq}")
        self._queue.append(req)

    def reseed(self, key: jax.Array) -> None:
        """Replace the sampling PRNG key (no-op for greedy decoding)."""
        self._state = self._state._replace(key=key)

    def _admit(self, slot: int, req: Request) -> None:
        sv = self.serve
        prompt = np.asarray(req.tokens, np.int32)
        S = len(prompt)
        hit = self.prefix_cache.lookup(prompt, max_suffix=self.max_suffix)
        if hit is not None:
            plen, block_np = hit
            self.prefix_cache.touch(prompt)      # hits keep counts fresh
            block = jax.tree.map(jnp.asarray, block_np)
            forced_tail = prompt[plen:]          # fed after cur, may be empty
        else:
            admit_plen = self.prefix_cache.observe(prompt)
            S_b = min(_bucket(S, sv.prefill_bucket), sv.max_seq)
            padded = np.zeros((1, S_b), np.int32)
            padded[0, :S] = prompt
            _, pre = self._prefill(self.params, {"tokens": jnp.asarray(padded)})
            block = pre["kv"]
            if admit_plen is not None:
                self.prefix_cache.admit(
                    prompt, admit_plen,
                    jax.tree.map(lambda a: a[:, :, :admit_plen], block))
            plen = S
            forced_tail = prompt[S:]             # empty
        # resume at plen-1 by re-feeding the last covered prompt token: its
        # KV row recomputes bit-identically and its logits feed the first
        # forced/sampled step in-graph.
        cur_tok = int(prompt[plen - 1])
        start = plen - 1
        fbuf = np.zeros((self.max_suffix,), np.int32)
        fbuf[:len(forced_tail)] = forced_tail
        st = self._state
        st = st._replace(
            cache=self._insert_fn(st.cache, block, jnp.int32(slot)),
            cur=st.cur.at[slot, 0].set(cur_tok),
            pos=st.pos.at[slot].set(start),
            remaining=st.remaining.at[slot].set(req.max_new),
            forced=st.forced.at[slot].set(jnp.asarray(fbuf)),
            forced_n=st.forced_n.at[slot].set(len(forced_tail)),
            forced_i=st.forced_i.at[slot].set(0),
        )
        self._state = st
        self._slot_req[slot] = req
        self._slot_out[slot] = []
        self._slot_hit[slot] = hit is not None

    def _retire(self) -> List[Completion]:
        done: List[Completion] = []
        remaining = np.asarray(self._state.remaining)
        for s, req in enumerate(self._slot_req):
            if req is not None and remaining[s] == 0:
                done.append(Completion(
                    rid=req.rid, prompt_len=len(req.tokens),
                    tokens=np.asarray(self._slot_out[s][:req.max_new],
                                      np.int32),
                    prefix_hit=self._slot_hit[s]))
                self._slot_req[s] = None
                self._slot_out[s] = []
        self.completed.extend(done)
        return done

    @property
    def pending(self) -> bool:
        """True while any request is queued or occupying a slot — the
        public drain condition (``while sched.pending: sched.step()``)."""
        return bool(self._queue) or any(
            r is not None for r in self._slot_req)

    def step(self) -> List[Completion]:
        """One scheduler round: admit queued requests into free slots, run
        one compiled decode chunk, collect emitted tokens, retire finished
        requests.  Returns the requests completed this round."""
        for s in range(self.serve.max_batch):
            if self._slot_req[s] is None and self._queue:
                self._admit(s, self._queue.pop(0))
        if not any(r is not None for r in self._slot_req):
            return []
        self._state, toks, emits = self._chunk_fn(self.params, self._state)
        self.decode_steps += self.serve.decode_chunk
        toks = np.asarray(toks)
        emits = np.asarray(emits)
        for t in range(toks.shape[0]):
            for s in range(toks.shape[1]):
                if emits[t, s] and self._slot_req[s] is not None:
                    self._slot_out[s].append(int(toks[t, s]))
        return self._retire()

    def run(self, requests: Optional[List[Request]] = None
            ) -> List[Completion]:
        """Drain: submit ``requests`` (if given) and step until every
        queued and in-flight request has completed."""
        for r in requests or []:
            self.submit(r)
        done: List[Completion] = []
        while self.pending:
            done.extend(self.step())
        return done

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------

    @property
    def decode_compilations(self) -> int:
        """Number of times the chunked decode step has been compiled —
        the engine's contract is that this is 1 for its whole lifetime,
        regardless of the request mix."""
        return self._chunk_fn._cache_size()

    @property
    def state(self) -> DecodeState:
        return self._state

    def kv_cache_bytes(self) -> int:
        return sum(int(a.size) * int(a.dtype.itemsize)
                   for a in jax.tree.leaves(self._state.cache))
