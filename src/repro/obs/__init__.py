"""Serve-path observability: request tracing, windowed metrics, and
sketch-fidelity telemetry.

Layering::

    trace.py    Chrome trace-event recorder (sampled, bounded, host-only)
    metrics.py  counter / gauge / log-bucket-histogram registry with
                windowed (interval-delta) snapshots
    export.py   trace JSON + metrics JSONL + Prometheus text sinks
    __init__    ServeObserver — the one object the serve layer talks to

The serve layer (``repro.serve``) never imports trace/metrics/export
directly: the scheduler and the async front-end hold an optional
``obs`` attribute (a ``ServeObserver`` or ``None``) and guard every
hook with ``if self.obs is not None`` — observability off means zero
extra work beyond one attribute check per site.  Every hook consumes
host-side values the pump already holds (mirrors, counters, wall-clock
durations), so enabling observability adds ZERO device syncs to the
hot path; the only exception is the opt-in sketch-fidelity probe,
which runs at the existing per-round ``collect()`` sync point and only
at its configured cadence.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Set, Union

from repro.obs.export import (MetricsJsonlWriter, prometheus_text,
                              write_trace)
from repro.obs.metrics import (Counter, Gauge, Histogram,
                               MetricsRegistry)
from repro.obs.trace import Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "MetricsJsonlWriter", "ServeObserver", "Tracer",
    "prometheus_text", "write_trace",
]

_MAX_WINDOWS = 512          # retained in-memory snapshots (tests, CLI)


class ServeObserver:
    """Facade bundling one tracer + one metrics registry + one JSONL
    sink behind the semantic hooks the serve layer calls.

    Construction knobs:
      ``tracer``            a ``Tracer`` or None (tracing off)
      ``registry``          shared ``MetricsRegistry`` (default: fresh)
      ``metrics_path``      JSONL file for windowed snapshots, or None
      ``metrics_interval``  seconds between windows flushed by
                            ``maybe_flush`` (<= 0: flush every call)
      ``fidelity_every``    sketch-fidelity probe cadence in decode
                            rounds (0 = probe off; see
                            ``kv_sketch.tail_row_spread``)

    Thread-safety note: hooks append to python lists/dicts from the
    pump task and from ``collect()`` running in a worker thread, but
    never concurrently — the pump awaits the collect thread, so at most
    one of them is inside the observer at a time.
    """

    def __init__(self, tracer: Optional[Tracer] = None,
                 registry: Optional[MetricsRegistry] = None,
                 metrics_path: Optional[str] = None,
                 metrics_interval: float = 0.5,
                 fidelity_every: int = 0):
        self.tracer = tracer
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self.metrics_interval = float(metrics_interval)
        self.fidelity_every = int(fidelity_every)
        self.windows: List[Dict[str, Any]] = []
        self._jsonl = (MetricsJsonlWriter(metrics_path)
                       if metrics_path else None)
        self._last_flush = time.perf_counter()
        self._queued_ts: Dict[int, float] = {}     # rid -> submit time
        self._last_tok: Dict[int, float] = {}      # rid -> last delivery
        self._active: Set[int] = set()             # rids with open span
        # the scheduler reports request_finished (inside collect) BEFORE
        # the front-end fans the final chunk's tokens out, so the timing
        # state moves here at finish and the trailing tokens_delivered
        # consumes it — a one-chunk request still gets its TTFT
        self._finished_ts: Dict[int, tuple] = {}

    # -- request lifecycle ---------------------------------------------

    def request_queued(self, rid: int, prompt_len: int,
                       priority: int) -> None:
        now = time.perf_counter()
        self._queued_ts[rid] = now
        self.registry.counter("serve.requests_submitted").inc()
        tr = self.tracer
        if tr is not None and tr.sampled(rid):
            tr.begin_async("request", rid, f"req{rid}",
                           {"prompt_len": int(prompt_len),
                            "priority": int(priority)})

    def request_admitted(self, rid: int, slot: int,
                         prefix_hit: bool) -> None:
        now = time.perf_counter()
        t0 = self._queued_ts.get(rid)
        if t0 is not None:
            self.registry.hist("serve.queue_wait_s").observe(now - t0)
        self.registry.counter("serve.requests_admitted").inc()
        self._active.add(rid)
        tr = self.tracer
        if tr is not None and tr.sampled(rid):
            tr.begin_async("request", rid, "active",
                           {"slot": int(slot),
                            "prefix_hit": bool(prefix_hit)})

    def admission_deferred(self, rid: int) -> None:
        """Head-of-queue request could not be admitted (pool pressure /
        CoW headroom): counted so stalls are visible in windows."""
        self.registry.counter("serve.admission_deferred").inc()

    def request_preempted(self, rid: int, slot: int,
                          n_emitted: int) -> None:
        self.registry.counter("serve.preemptions").inc()
        tr = self.tracer
        if rid in self._active:
            self._active.discard(rid)
            if tr is not None and tr.sampled(rid):
                tr.end_async("request", rid, "active",
                             {"preempted": True,
                              "emitted": int(n_emitted)})
        if tr is not None and tr.sampled(rid):
            tr.instant("preempt", {"rid": int(rid), "slot": int(slot)})

    def request_finished(self, rid: int, status: str,
                         n_tokens: int) -> None:
        self.registry.counter(f"serve.completions.{status}").inc()
        tr = self.tracer
        sampled = tr is not None and tr.sampled(rid)
        if rid in self._active:
            self._active.discard(rid)
            if sampled:
                tr.end_async("request", rid, "active")
        if sampled:
            tr.end_async("request", rid, f"req{rid}",
                         {"status": status, "tokens": int(n_tokens)})
        t0 = self._queued_ts.pop(rid, None)
        lt = self._last_tok.pop(rid, None)
        if t0 is not None or lt is not None:
            self._finished_ts[rid] = (t0, lt)
            if len(self._finished_ts) > 1024:
                # a finished request's final delivery lands within one
                # pump iteration; older entries were never claimed
                # (closed-batch callers with no stream fan-out), so
                # dropping the oldest half is safe bounded cleanup
                for k in list(self._finished_ts)[:512]:
                    self._finished_ts.pop(k, None)

    # -- token delivery (front-end) ------------------------------------

    def tokens_delivered(self, rid: int, n_new: int) -> None:
        """``n_new`` tokens just fanned out to a stream handle.  First
        delivery records TTFT (submit -> first token); later deliveries
        record the per-delivery gap as inter-token latency (tokens
        inside one delivered chunk land together, so the gap IS the
        perceived ITL at chunk granularity)."""
        if n_new <= 0:
            return
        now = time.perf_counter()
        t0 = self._queued_ts.get(rid)
        last = self._last_tok.get(rid)
        finished = t0 is None and last is None
        if finished:
            t0, last = self._finished_ts.pop(rid, (None, None))
        if last is not None:
            self.registry.hist("serve.itl_s").observe(now - last)
        elif t0 is not None:
            self.registry.hist("serve.ttft_s").observe(now - t0)
        if not finished:
            self._last_tok[rid] = now
        self.registry.counter("serve.tokens_delivered").inc(n_new)

    def backpressure_wait(self, dur_s: float) -> None:
        self.registry.counter("serve.backpressure_stalls").inc()
        self.registry.hist("serve.backpressure_wait_s").observe(dur_s)

    # -- pump phases / engine events -----------------------------------

    def pump_span(self, name: str, t0_s: float, dur_s: float,
                  args: Optional[dict] = None) -> None:
        """One host-side pump phase ("dispatch" host time, "collect"
        block time) as an "X" span; also feeds the phase histogram."""
        self.registry.hist(f"pump.{name}_s").observe(dur_s)
        if self.tracer is not None:
            self.tracer.complete(name, t0_s * 1e6, dur_s * 1e6, args)

    def prefill_span(self, slot: int, off: int, rows: int,
                     dur_s: float) -> None:
        """Host dispatch time of one chunked-prefill step (the device
        work is async; this is the pump-side cost)."""
        self.registry.counter("serve.prefill_chunks").inc()
        if self.tracer is not None:
            t1 = time.perf_counter()
            self.tracer.complete("prefill_chunk", (t1 - dur_s) * 1e6,
                                 dur_s * 1e6,
                                 {"slot": int(slot), "off": int(off),
                                  "rows": int(rows)})

    def fold(self, slot: int, rows: int) -> None:
        """``rows`` KV rows folded from a slot's exact window into its
        count-sketch tail (their pool blocks freed)."""
        self.registry.counter("serve.fold_events").inc()
        self.registry.counter("serve.fold_rows").inc(rows)
        if self.tracer is not None:
            self.tracer.instant("fold", {"slot": int(slot),
                                         "rows": int(rows)})

    def spec_round(self, rid: int, proposed: int,
                   accepted: int) -> None:
        self.registry.counter("spec.rounds").inc()
        self.registry.counter("spec.proposed").inc(proposed)
        self.registry.counter("spec.accepted").inc(accepted)
        tr = self.tracer
        if tr is not None and tr.sampled(rid):
            tr.instant("spec_round", {"rid": int(rid),
                                      "proposed": int(proposed),
                                      "accepted": int(accepted)})

    def prefix_event(self, kind: str) -> None:
        """Prefix-cache outcome: hit / miss / admit / evict / defer."""
        self.registry.counter(f"prefix.{kind}").inc()

    def chunk_collected(self, tokens: int, queue_depth: int,
                        active_slots: int) -> None:
        """End of one decode round (the per-round sync point)."""
        self.registry.counter("serve.tokens_committed").inc(tokens)
        self.registry.counter("serve.decode_rounds").inc()
        self.registry.gauge("serve.queue_depth").set(queue_depth)
        self.registry.gauge("serve.active_slots").set(active_slots)
        if self.tracer is not None:
            self.tracer.counter("engine",
                                {"queue_depth": int(queue_depth),
                                 "active_slots": int(active_slots)})

    def fidelity(self, slot: int, rid: int, fold_rows: int,
                 spread: float) -> None:
        """Sketch-fidelity probe sample: relative spread of the per-
        hash-row tail estimates for one folded slot (0 = rows agree
        perfectly; grows with collision variance)."""
        self.registry.gauge(f"kv.tail_spread.slot{slot}").set(spread)
        self.registry.hist("kv.tail_spread").observe(spread)
        tr = self.tracer
        if tr is not None:
            tr.counter(f"tail_spread/slot{slot}",
                       {"spread": float(spread)})
            if tr.sampled(rid):
                tr.instant("tail_fidelity",
                           {"rid": int(rid), "slot": int(slot),
                            "fold_rows": int(fold_rows),
                            "spread": float(spread)})

    # -- windowing / export --------------------------------------------

    def maybe_flush(self, stats: Union[Callable[[], Any], Any,
                                       None] = None) -> None:
        """Flush a metrics window if ``metrics_interval`` has elapsed
        (<= 0: every call).  Cheap no-op otherwise."""
        if time.perf_counter() - self._last_flush \
                < self.metrics_interval:
            return
        self.flush(stats)

    def flush(self, stats: Union[Callable[[], Any], Any,
                                 None] = None) -> Dict[str, Any]:
        """Force one metrics window: mirror ``stats`` (an EngineStats
        or a callable producing one) into the registry, snapshot,
        retain, and write to the JSONL sink if configured."""
        if stats is not None:
            st = stats() if callable(stats) else stats
            self.registry.update_from_stats(st)
        w = self.registry.window()
        self.windows.append(w)
        if len(self.windows) > _MAX_WINDOWS:
            del self.windows[:-_MAX_WINDOWS]
        if self._jsonl is not None:
            self._jsonl.write(w)
        self._last_flush = time.perf_counter()
        return w

    def close(self, stats: Union[Callable[[], Any], Any, None] = None,
              trace_path: Optional[str] = None) -> None:
        """Final flush + close sinks; writes the trace file if a path
        is given and tracing was on."""
        self.flush(stats)
        if self._jsonl is not None:
            self._jsonl.close()
        if trace_path and self.tracer is not None:
            write_trace(self.tracer, trace_path)
