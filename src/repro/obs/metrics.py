"""Windowed serve metrics: counters, gauges, log-bucket histograms.

``EngineStats`` is a cumulative exit snapshot; operating a serving
engine needs *rates over an interval* — tok/s right now, TTFT p99 over
the last half second, fold rows per second — not lifetime totals.  This
module layers a small registry on top:

  * ``Counter``  — monotonic totals.  Fed either incrementally
    (``inc``) by observer hooks or absolutely (``set_total``) from the
    cumulative ``EngineStats`` fields, so windowed deltas of an
    engine counter always sum back to the engine's final snapshot.
  * ``Gauge``    — last-value instruments (queue depth, active slots,
    per-slot tail-fidelity spread).
  * ``Histogram``— geometric (log-spaced) buckets with interpolated
    quantiles; fixed memory regardless of sample count, and windowed
    quantiles computed over per-interval bucket deltas.

``MetricsRegistry.window()`` produces one self-contained snapshot dict:
interval deltas and rates for every counter, current gauge values, and
delta-count/sum/p50/p90/p99 for every histogram.  Snapshots are plain
JSON-able dicts — the JSONL exporter writes them verbatim.

``update_from_stats`` maps an ``EngineStats`` dataclass into the
registry using the per-field ``kind`` metadata tags (counter / gauge /
peak) that ``EngineStats.merge`` also uses, so the merge semantics and
the metrics semantics can never drift apart.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
import time
from typing import Any, Dict, List, Optional


class Counter:
    """Monotonic counter.  ``inc`` for event-driven totals,
    ``set_total`` to mirror an externally-cumulated value."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def set_total(self, v: float) -> None:
        self.value = float(v)


class Gauge:
    """Last-value instrument."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Log-bucket histogram: bucket ``i`` counts samples in
    ``(lo * growth**i, lo * growth**(i+1)]`` plus one overflow bucket,
    so relative quantile error is bounded by ``growth`` at constant
    memory.  Defaults cover 1 microsecond .. ~3 hours of latency."""

    def __init__(self, lo: float = 1e-6, hi: float = 1e4,
                 growth: float = 1.3):
        assert lo > 0 and hi > lo and growth > 1.0
        self.lo = float(lo)
        self.hi = float(hi)
        self.growth = float(growth)
        n = int(math.ceil(math.log(hi / lo) / math.log(growth)))
        # bounds[i] is bucket i's inclusive upper edge
        self.bounds = [lo * growth ** (i + 1) for i in range(n)]
        self.counts = [0] * (n + 1)            # + overflow bucket
        self.count = 0
        self.sum = 0.0
        self.max = 0.0

    def observe(self, x: float) -> None:
        x = float(x)
        i = bisect.bisect_left(self.bounds, x)
        self.counts[min(i, len(self.counts) - 1)] += 1
        self.count += 1
        self.sum += x
        if x > self.max:
            self.max = x

    def _edges(self, i: int) -> tuple:
        lo = self.lo * self.growth ** i
        if i < len(self.bounds):
            return lo, self.bounds[i]
        return lo, max(self.max, lo * self.growth)   # overflow bucket

    def quantile(self, q: float, counts: Optional[List[int]] = None,
                 total: Optional[int] = None) -> float:
        """Geometrically interpolated q-quantile over ``counts``
        (default: the cumulative counts)."""
        counts = self.counts if counts is None else counts
        total = sum(counts) if total is None else total
        if total <= 0:
            return 0.0
        target = q * total
        cum = 0
        for i, c in enumerate(counts):
            cum += c
            if c and cum >= target:
                lo, hi = self._edges(i)
                frac = (target - (cum - c)) / c
                return lo * (hi / lo) ** max(frac, 0.0)
        lo, hi = self._edges(len(counts) - 1)
        return hi


class MetricsRegistry:
    """Named counters / gauges / histograms plus windowing state (the
    previous snapshot each ``window()`` call diffs against)."""

    def __init__(self) -> None:
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.hists: Dict[str, Histogram] = {}
        self._seq = 0
        self._last = time.perf_counter()
        self._prev_counter: Dict[str, float] = {}
        self._prev_hist: Dict[str, tuple] = {}

    # -- instrument lookup (get-or-create) -----------------------------

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge()
        return g

    def hist(self, name: str, **kw: Any) -> Histogram:
        h = self.hists.get(name)
        if h is None:
            h = self.hists[name] = Histogram(**kw)
        return h

    # -- EngineStats bridge --------------------------------------------

    def update_from_stats(self, stats: Any,
                          prefix: str = "engine.") -> None:
        """Mirror a kind-tagged stats dataclass (``EngineStats``) into
        the registry: ``counter`` fields become monotonic counters
        (windowed deltas therefore sum back to the cumulative
        snapshot), ``gauge``/``peak``/``geometry`` fields become
        gauges."""
        for f in dataclasses.fields(stats):
            v = getattr(stats, f.name)
            if not isinstance(v, (int, float)) or isinstance(v, bool):
                continue
            kind = f.metadata.get("kind", "counter")
            if kind == "counter":
                self.counter(prefix + f.name).set_total(v)
            else:
                self.gauge(prefix + f.name).set(v)

    # -- windowing -----------------------------------------------------

    def window(self) -> Dict[str, Any]:
        """One windowed snapshot: per-counter {total, delta, rate},
        current gauges, per-histogram interval stats.  Diffing state
        advances, so consecutive windows tile the timeline and their
        counter deltas sum to the final totals."""
        now = time.perf_counter()
        dur = max(now - self._last, 1e-9)
        self._last = now
        self._seq += 1

        counters: Dict[str, Any] = {}
        for n, c in self.counters.items():
            prev = self._prev_counter.get(n, 0.0)
            d = c.value - prev
            self._prev_counter[n] = c.value
            counters[n] = {"total": c.value, "delta": d, "rate": d / dur}

        hists: Dict[str, Any] = {}
        for n, h in self.hists.items():
            pc, pn, ps = self._prev_hist.get(
                n, ([0] * len(h.counts), 0, 0.0))
            if len(pc) != len(h.counts):
                pc = [0] * len(h.counts)
            dc = [a - b for a, b in zip(h.counts, pc)]
            dn = h.count - pn
            self._prev_hist[n] = (list(h.counts), h.count, h.sum)
            hists[n] = {
                "count": dn, "sum": h.sum - ps,
                "p50": h.quantile(0.50, dc, dn),
                "p90": h.quantile(0.90, dc, dn),
                "p99": h.quantile(0.99, dc, dn),
                "max": h.max,
            }

        return {"ts": time.time(), "seq": self._seq, "dur_s": dur,
                "counters": counters,
                "gauges": {n: g.value for n, g in self.gauges.items()},
                "hists": hists}
