"""Sampled low-overhead request/phase tracer for the serve path.

Records the full request lifecycle — queued -> admitted -> prefill
chunks -> decode chunks -> fold events -> speculative rounds ->
completion / cancel / expire / preempt — as Chrome trace-event JSON
(the ``traceEvents`` array format), loadable directly in Perfetto or
``chrome://tracing``.

Design constraints (the whole point of this module):

  * HOST-ONLY: every hook takes values the pump already holds on the
    host (mirrors, counters, wall-clock durations).  Nothing here ever
    touches a jax array, so tracing adds ZERO device syncs to the hot
    path — the one sync per round stays ``collect()``.
  * monotonic clock: timestamps are ``time.perf_counter()`` in
    microseconds (the trace-event unit), immune to wall-clock steps.
  * sampled: per-request lifecycle events are gated by a deterministic
    hash of the rid against ``sample_rate``, so heavy traffic can trace
    a stable subset; per-chunk pump spans are bounded (one per phase
    per round) and always recorded.
  * bounded: at most ``max_events`` events are retained; overflow is
    counted in ``dropped`` and surfaced as trace metadata, never an
    allocation blow-up.

Event vocabulary (Chrome trace-event phases):

  "b"/"e"  async nestable spans keyed by (cat, id) — one outer
           ``req<rid>`` span per request (queued -> resolved) with a
           nested ``active`` span per residency (admission -> retire /
           preempt; a preempted request opens a fresh ``active`` span
           when it is re-admitted);
  "X"      complete spans for pump phases (dispatch host time, collect
           block time, per-chunk prefill dispatch) on tid 1;
  "i"      instants for point events (folds, preemptions, expiries);
  "C"      counter tracks (queue depth / active slots per round).
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

# pid/tid layout: one fake process; tid 0 carries request spans and
# counters, tid 1 carries pump-phase spans, so Perfetto renders the
# request timeline and the engine phases as two parallel tracks
_PID = 1
_TID_REQ = 0
_TID_PUMP = 1

# Knuth multiplicative hash: deterministic rid -> [0, 1) sampling that
# needs no RNG state and never re-decides for the same request
_HASH_MULT = 2654435761


class Tracer:
    """Append-only trace-event recorder.  All methods are cheap dict
    appends; formatting costs are paid once at export."""

    def __init__(self, sample_rate: float = 1.0,
                 max_events: int = 200_000):
        self.sample_rate = float(sample_rate)
        self.max_events = int(max_events)
        self.dropped = 0
        self._events: List[Dict[str, Any]] = []

    # -- clock / sampling ----------------------------------------------

    @staticmethod
    def now() -> float:
        """Monotonic timestamp in trace-event microseconds."""
        return time.perf_counter() * 1e6

    def sampled(self, rid: int) -> bool:
        """Deterministic per-request sampling decision: the same rid
        always resolves the same way, so a request's span can never be
        half-recorded."""
        if self.sample_rate >= 1.0:
            return True
        if self.sample_rate <= 0.0:
            return False
        h = (int(rid) * _HASH_MULT) & 0xFFFFFFFF
        return h / 4294967296.0 < self.sample_rate

    # -- event emission ------------------------------------------------

    def _push(self, ev: Dict[str, Any]) -> None:
        if len(self._events) >= self.max_events:
            self.dropped += 1
            return
        self._events.append(ev)

    def begin_async(self, cat: str, id_: int, name: str,
                    args: Optional[dict] = None,
                    ts: Optional[float] = None) -> None:
        self._push({"ph": "b", "cat": cat, "id": int(id_), "name": name,
                    "pid": _PID, "tid": _TID_REQ,
                    "ts": self.now() if ts is None else ts,
                    "args": args or {}})

    def end_async(self, cat: str, id_: int, name: str,
                  args: Optional[dict] = None,
                  ts: Optional[float] = None) -> None:
        self._push({"ph": "e", "cat": cat, "id": int(id_), "name": name,
                    "pid": _PID, "tid": _TID_REQ,
                    "ts": self.now() if ts is None else ts,
                    "args": args or {}})

    def instant(self, name: str, args: Optional[dict] = None,
                ts: Optional[float] = None, tid: int = _TID_REQ) -> None:
        self._push({"ph": "i", "name": name, "pid": _PID, "tid": tid,
                    "ts": self.now() if ts is None else ts, "s": "t",
                    "args": args or {}})

    def complete(self, name: str, ts_us: float, dur_us: float,
                 args: Optional[dict] = None,
                 tid: int = _TID_PUMP) -> None:
        """One "X" complete span: ``ts_us`` start, ``dur_us`` duration,
        both in trace microseconds (use ``Tracer.now()``)."""
        self._push({"ph": "X", "name": name, "pid": _PID, "tid": tid,
                    "ts": ts_us, "dur": max(dur_us, 0.0),
                    "args": args or {}})

    def counter(self, name: str, values: Dict[str, float],
                ts: Optional[float] = None) -> None:
        """One "C" counter sample; ``values`` become stacked series."""
        self._push({"ph": "C", "name": name, "pid": _PID, "tid": _TID_REQ,
                    "ts": self.now() if ts is None else ts,
                    "args": dict(values)})

    # -- export --------------------------------------------------------

    def __len__(self) -> int:
        return len(self._events)

    def events(self) -> List[Dict[str, Any]]:
        """The recorded trace events (plus a metadata instant recording
        any overflow drops), ready for ``{"traceEvents": ...}``."""
        out = list(self._events)
        if self.dropped:
            out.append({"ph": "i", "name": "tracer_dropped_events",
                        "pid": _PID, "tid": _TID_REQ, "ts": self.now(),
                        "s": "g", "args": {"dropped": self.dropped}})
        return out
