"""Exporters for the serve observability layer.

Three sinks, all dependency-free:

  * ``write_trace``     — Chrome trace-event JSON (``{"traceEvents":
    [...]}``) from a ``Tracer``; open the file in Perfetto
    (https://ui.perfetto.dev) or ``chrome://tracing``.
  * ``MetricsJsonlWriter`` — one ``MetricsRegistry.window()`` snapshot
    per line, flushed per write so a crashed or killed server still
    leaves a parseable stream.
  * ``prometheus_text`` — Prometheus text exposition (v0.0.4) of the
    registry's current state, for scrape endpoints or debugging dumps.
"""
from __future__ import annotations

import json
import re
from typing import IO, Any, Dict, Optional

from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer

_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")


def write_trace(tracer: Tracer, path: str) -> int:
    """Write the tracer's events as Chrome trace-event JSON; returns
    the number of events written."""
    events = tracer.events()
    with open(path, "w") as fh:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, fh)
    return len(events)


class MetricsJsonlWriter:
    """Append-only JSONL sink for windowed metric snapshots."""

    def __init__(self, path: str):
        self.path = path
        self.written = 0
        self._fh: Optional[IO[str]] = open(path, "w")

    def write(self, window: Dict[str, Any]) -> None:
        if self._fh is None:
            return
        self._fh.write(json.dumps(window) + "\n")
        self._fh.flush()
        self.written += 1

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


def _sanitize(name: str) -> str:
    return _NAME_RE.sub("_", name)


def prometheus_text(reg: MetricsRegistry,
                    namespace: str = "repro") -> str:
    """Render the registry in Prometheus text exposition format.
    Histograms are exposed as summaries (cumulative ``_count`` /
    ``_sum`` plus quantile samples) since the log buckets are an
    internal representation."""
    lines = []
    for name, c in sorted(reg.counters.items()):
        m = f"{namespace}_{_sanitize(name)}"
        lines.append(f"# TYPE {m} counter")
        lines.append(f"{m} {c.value:g}")
    for name, g in sorted(reg.gauges.items()):
        m = f"{namespace}_{_sanitize(name)}"
        lines.append(f"# TYPE {m} gauge")
        lines.append(f"{m} {g.value:g}")
    for name, h in sorted(reg.hists.items()):
        m = f"{namespace}_{_sanitize(name)}"
        lines.append(f"# TYPE {m} summary")
        for q in (0.5, 0.9, 0.99):
            lines.append(
                f'{m}{{quantile="{q}"}} {h.quantile(q):g}')
        lines.append(f"{m}_sum {h.sum:g}")
        lines.append(f"{m}_count {h.count}")
    return "\n".join(lines) + "\n"
