"""Robust Tensor Power Method (Anandkumar et al. 2014) — plain and sketched.

For each rank-1 component: run L random initializations for T power
iterations u <- T(I,u,u)/||T(I,u,u)||, keep the best by lambda = T(u,u,u),
deflate, repeat.  The sketched variants replace the two contractions with
their CS/TS/HCS/FCS estimators (paper Section 4.1.1, Table 1).

The symmetric method is used on symmetric tensors (paper's synthetic
experiments); ``rtpm_asymmetric`` does alternating rank-1 updates
(Anandkumar et al. 2014b) for real-world tensors.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import (
    ModeHash, cs_apply, fcs_general, fcs_tiuu, fcs_tuuu, hcs_general,
    make_tensor_hashes, ts_general, ts_tiuu, ts_tuuu,
)


# ---------------------------------------------------------------------------
# Contraction oracles: given T (or its sketch), return the two contraction
# functions tiuu(u) -> (I,), tuuu(u) -> scalar.
# ---------------------------------------------------------------------------


def plain_oracle(T: jax.Array):
    def tiuu(u):
        return jnp.einsum("abc,b,c->a", T, u, u)

    def tuuu(u):
        return jnp.einsum("abc,a,b,c->", T, u, u, u)
    return tiuu, tuuu


def fcs_oracle(T: jax.Array, hashes: Sequence[ModeHash]):
    sk = fcs_general(T, hashes)

    def tiuu(u):
        return jnp.median(fcs_tiuu(sk, u, hashes), axis=0)

    def tuuu(u):
        return jnp.median(fcs_tuuu(sk, u, hashes), axis=0)
    return tiuu, tuuu


def ts_oracle(T: jax.Array, hashes: Sequence[ModeHash]):
    sk = ts_general(T, hashes)

    def tiuu(u):
        return jnp.median(ts_tiuu(sk, u, hashes), axis=0)

    def tuuu(u):
        return jnp.median(ts_tuuu(sk, u, hashes), axis=0)
    return tiuu, tuuu


def cs_oracle(T: jax.Array, hashes_long: ModeHash):
    """Plain CS on vec(T) with a LONG hash pair (the O(prod I_n) storage
    baseline the paper compares against).  hashes_long: ModeHash over
    I = prod(dims)."""
    I = T.shape[0]
    vec = T.reshape(-1)
    sk = cs_apply(vec, hashes_long)                    # (D, J)

    def estimate_inner(other_vec):
        sk2 = cs_apply(other_vec, hashes_long)
        return jnp.median(jnp.sum(sk * sk2, axis=-1))

    def tiuu(u):
        outer = jnp.einsum("b,c->bc", u, u).reshape(-1)

        def one(i):
            e = jnp.zeros((I,)).at[i].set(1.0)
            return estimate_inner(jnp.einsum("a,b->ab", e, outer).reshape(-1))
        return jax.lax.map(one, jnp.arange(I))

    def tuuu(u):
        v = jnp.einsum("a,b,c->abc", u, u, u).reshape(-1)
        return estimate_inner(v)
    return tiuu, tuuu


def hcs_oracle(T: jax.Array, hashes: Sequence[ModeHash]):
    """HCS-based contraction (Shi 2019): contract the SKETCHED tensor with
    CS(u) directly — HCS(T)(I, CS2(u), CS3(u)) then decompress mode 1."""
    sk = hcs_general(T, hashes)                        # (D, J1, J2, J3)
    mh1, mh2, mh3 = hashes

    def tiuu(u):
        c2 = cs_apply(u, mh2)                          # (D, J2)
        c3 = cs_apply(u, mh3)
        z = jnp.einsum("dabc,db,dc->da", sk, c2, c3)   # (D, J1)
        est = jax.vmap(lambda zd, h, s: s * zd[h])(z, mh1.h, mh1.s)
        return jnp.median(est, axis=0)

    def tuuu(u):
        c1 = cs_apply(u, mh1)
        c2 = cs_apply(u, mh2)
        c3 = cs_apply(u, mh3)
        return jnp.median(jnp.einsum("dabc,da,db,dc->d", sk, c1, c2, c3))
    return tiuu, tuuu


ORACLES = {
    "plain": plain_oracle,
    "fcs": fcs_oracle,
    "ts": ts_oracle,
    "cs": cs_oracle,
    "hcs": hcs_oracle,
}


# ---------------------------------------------------------------------------
# Symmetric RTPM
# ---------------------------------------------------------------------------


def _nan_safe_argmax(vals: jax.Array) -> jax.Array:
    """Best-of-inits selection that a divergent candidate cannot hijack.

    Same guard as cpd/als.py's multi-init probe: a power iteration that
    diverges under a noisy sketched oracle yields lambda = NaN (or +/-inf),
    and jnp.argmax propagates NaN as the "max" — one bad init would then
    poison the deflation of every later component.  Non-finite candidates
    are demoted to -inf so a finite init always wins when one exists."""
    return jnp.argmax(jnp.where(jnp.isfinite(vals), vals, -jnp.inf))


def rtpm(tiuu: Callable, tuuu: Callable, I: int, rank: int, key: jax.Array,
         n_inits: int = 15, n_iters: int = 20,
         deflate: Optional[Callable] = None
         ) -> Tuple[jax.Array, jax.Array]:
    """Returns (lambdas (rank,), factors (I, rank)).

    ``deflate(tiuu, tuuu, lam, u)`` must return updated oracles; the default
    subtracts the rank-1 contribution analytically (works for any oracle
    since the contractions are linear in T)."""
    lams = []
    us = []

    def power(u0, tiuu_fn):
        def step(u, _):
            v = tiuu_fn(u)
            return v / (jnp.linalg.norm(v) + 1e-12), None
        u, _ = jax.lax.scan(step, u0, None, length=n_iters)
        return u

    cur_tiuu, cur_tuuu = tiuu, tuuu
    for r in range(rank):
        key, k1 = jax.random.split(key)
        inits = jax.random.normal(k1, (n_inits, I))
        inits = inits / jnp.linalg.norm(inits, axis=1, keepdims=True)
        cands = jax.lax.map(lambda u0: power(u0, cur_tiuu), inits)
        vals = jax.lax.map(cur_tuuu, cands)
        best = _nan_safe_argmax(vals)
        u = power(cands[best], cur_tiuu)               # a few extra polish iters
        lam = cur_tuuu(u)
        lams.append(lam)
        us.append(u)

        # deflation: T <- T - lam u^3 ; contractions update analytically
        def make_deflated(prev_tiuu, prev_tuuu, lam=lam, u=u):
            def d_tiuu(v):
                return prev_tiuu(v) - lam * u * jnp.dot(u, v) ** 2

            def d_tuuu(v):
                return prev_tuuu(v) - lam * jnp.dot(u, v) ** 3
            return d_tiuu, d_tuuu

        cur_tiuu, cur_tuuu = make_deflated(cur_tiuu, cur_tuuu)

    return jnp.stack(lams), jnp.stack(us, axis=1)


def rtpm_decompose(T: jax.Array, rank: int, key: jax.Array,
                   method: str = "plain", hash_len: int = 1000,
                   n_sketches: int = 2, n_inits: int = 15, n_iters: int = 20
                   ) -> Tuple[jax.Array, jax.Array]:
    """End-to-end symmetric CPD of T (I,I,I) via (sketched) RTPM."""
    I = T.shape[0]
    if method == "plain":
        tiuu, tuuu = plain_oracle(T)
    elif method == "cs":
        from repro.core import make_mode_hash
        mh = make_mode_hash(key, I ** 3, hash_len, n_sketches)
        tiuu, tuuu = cs_oracle(T, mh)
    else:
        if method == "hcs":
            Js = [hash_len] * 3
        else:
            Js = [hash_len] * 3
        hashes = make_tensor_hashes(key, T.shape, Js, n_sketches)
        tiuu, tuuu = ORACLES[method](T, hashes)
    return rtpm(tiuu, tuuu, I, rank, key, n_inits, n_iters)


def cp_reconstruct(lams: jax.Array, U: jax.Array) -> jax.Array:
    return jnp.einsum("r,ar,br,cr->abc", lams, U, U, U)


def residual_norm(T: jax.Array, lams: jax.Array, U: jax.Array) -> jax.Array:
    R = cp_reconstruct(lams, U)
    return jnp.linalg.norm(T - R) / jnp.linalg.norm(T)
