"""CP-ALS (Kolda & Bader 2009) — plain and sketched (paper Section 4.1.2).

Each ALS sweep solves, for each mode, the least-squares problem against the
Khatri-Rao product of the other factors.  The MTTKRP columns are exactly the
contractions of Eq. 18 — T(I, b_r, c_r) etc. — so the sketched variants
estimate them with the Eq. 17 trick per rank column.
"""
from __future__ import annotations

import math
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import (
    ModeHash, cs_apply, fcs_general, make_tensor_hashes, ts_general,
)


def _solve(mttkrp: jax.Array, G: jax.Array) -> jax.Array:
    """mttkrp (I, R) @ pinv(G) with G = (B^T B) * (C^T C)."""
    return jnp.linalg.solve(G + 1e-6 * jnp.eye(G.shape[0]),
                            mttkrp.T).T


def _mttkrp_plain(T: jax.Array, B: jax.Array, C: jax.Array,
                  mode: int) -> jax.Array:
    if mode == 0:
        return jnp.einsum("abc,br,cr->ar", T, B, C)
    if mode == 1:
        return jnp.einsum("abc,ar,cr->br", T, B, C)
    return jnp.einsum("abc,ar,br->cr", T, B, C)


def _mttkrp_sketched(sk: jax.Array, hashes: Sequence[ModeHash],
                     B: jax.Array, C: jax.Array, mode: int,
                     circular: bool) -> jax.Array:
    """Columns r: T(I, b_r, c_r)-style contraction for the given free mode.
    sk is the (D, J~) FCS (or (D, J) TS) sketch of T."""
    order = {0: (0, 1, 2), 1: (1, 0, 2), 2: (2, 0, 1)}[mode]
    mh_free = hashes[order[0]]
    mh_b, mh_c = hashes[order[1]], hashes[order[2]]
    Jt = sk.shape[-1]

    fsk = jnp.fft.rfft(sk, n=Jt, axis=-1)

    def col(bc):
        b, c = bc
        csb = cs_apply(b, mh_b)
        csc = cs_apply(c, mh_c)
        f = (fsk * jnp.conj(jnp.fft.rfft(csb, n=Jt, axis=-1))
             * jnp.conj(jnp.fft.rfft(csc, n=Jt, axis=-1)))
        z = jnp.fft.irfft(f, n=Jt, axis=-1)
        if circular:
            est = jax.vmap(lambda zd, h, s: s * zd[h % Jt])(
                z, mh_free.h, mh_free.s)
        else:
            est = jax.vmap(lambda zd, h, s: s * zd[h])(z, mh_free.h, mh_free.s)
        return jnp.median(est, axis=0)

    cols = jax.lax.map(col, (B.T, C.T))               # (R, I_free)
    return cols.T


def als_decompose(T: jax.Array, rank: int, key: jax.Array,
                  method: str = "plain", hash_len: int = 3000,
                  n_sketches: int = 10, n_iters: int = 20,
                  n_inits: int = 3) -> Tuple[jax.Array, list]:
    """Asymmetric CP decomposition T ~= [[lam; A, B, C]].  Returns
    (lam (R,), [A, B, C]).

    Initialization: HOSVD plus (n_inits - 1) random inits, each probed for
    a few sweeps; the best continues.  HOSVD alone is NOT safe: when the
    unfolding spectrum is (near-)degenerate — e.g. orthonormal factors
    with equal weights — its leading singular vectors are an arbitrary
    rotation of the true factors, a near-saddle from which ALS swamps
    (observed: two columns chasing one component, residual pinned at 0.5).
    Random inits break the symmetry; probing keeps HOSVD's advantage when
    the spectrum is informative.
    """
    I1, I2, I3 = T.shape
    kA, kB, kC, kh = jax.random.split(key, 4)

    def _hosvd(mode, k, dim):
        M = jnp.moveaxis(T, mode, 0).reshape(dim, -1)
        u, _, _ = jnp.linalg.svd(M, full_matrices=False)
        base = u[:, :rank]
        if base.shape[1] < rank:
            base = jnp.pad(base, ((0, 0), (0, rank - base.shape[1])))
        return base + 0.01 * jax.random.normal(k, (dim, rank))

    inits = [(_hosvd(0, kA, I1), _hosvd(1, kB, I2), _hosvd(2, kC, I3))]
    for j in range(max(n_inits - 1, 0)):
        kj = jax.random.fold_in(key, j + 1)
        k1, k2, k3 = jax.random.split(kj, 3)
        inits.append((jax.random.normal(k1, (I1, rank)),
                      jax.random.normal(k2, (I2, rank)),
                      jax.random.normal(k3, (I3, rank))))

    sk = None
    hashes = None
    circular = method == "ts"
    if method in ("fcs", "ts"):
        hashes = make_tensor_hashes(kh, T.shape, hash_len, n_sketches)
        sk = (fcs_general if method == "fcs" else ts_general)(T, hashes)

    def mttkrp(Bm, Cm, mode):
        if method == "plain":
            return _mttkrp_plain(T, Bm, Cm, mode)
        return _mttkrp_sketched(sk, hashes, Bm, Cm, mode, circular)

    def sweep(A, B, C):
        G = (B.T @ B) * (C.T @ C)
        A = _solve(mttkrp(B, C, 0), G)
        A = A / (jnp.linalg.norm(A, axis=0) + 1e-12)
        G = (A.T @ A) * (C.T @ C)
        B = _solve(mttkrp(A, C, 1), G)
        B = B / (jnp.linalg.norm(B, axis=0) + 1e-12)
        G = (A.T @ A) * (B.T @ B)
        C = _solve(mttkrp(A, B, 2), G)
        # A, B are unit-norm when C is solved, so C's column norms carry
        # the full lambda.
        lam = jnp.linalg.norm(C, axis=0) + 1e-12
        return A, B, C / lam, lam

    probe_iters = min(max(2, n_iters // 4), n_iters)
    best = None
    best_res = jnp.inf
    for A, B, C in inits:
        lam = jnp.ones((rank,))
        for _ in range(probe_iters):
            A, B, C, lam = sweep(A, B, C)
        res_f = float(als_residual(T, lam, [A, B, C]))
        # NaN handling: a divergent probe (NaN residual) must neither
        # crash the unpack below nor shadow later finite candidates.
        better = (best is None or res_f < float(best_res)
                  or (math.isnan(float(best_res))
                      and not math.isnan(res_f)))
        if better:
            best, best_res = (A, B, C, lam), res_f
    A, B, C, lam = best
    for _ in range(n_iters - probe_iters):
        A, B, C, lam = sweep(A, B, C)
    return lam, [A, B, C]


def als_residual(T: jax.Array, lam: jax.Array, factors: list) -> jax.Array:
    A, B, C = factors
    R = jnp.einsum("r,ar,br,cr->abc", lam, A, B, C)
    return jnp.linalg.norm(T - R) / jnp.linalg.norm(T)
