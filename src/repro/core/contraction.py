"""Sketched tensor-contraction approximations (paper Section 3.3/4.3).

  T(u,u,u)   ~= < FCS(T), FCS(u o u o u) >                         (Eq. 16)
  T(I,u,u)_i ~= s_1(i) * z[h_1(i)],                                 (Eq. 17)
      z = irfft( rfft(FCS(T)) * conj(rfft(CS_2(u), J~))
                               * conj(rfft(CS_3(u), J~)) )
  (z is u-dependent but i-independent -> computed once per power iteration)

plus the Kronecker-product (Section 4.3.1) and mode-contraction
(Section 4.3.2) compress/decompress rules, and TS equivalents for the
paper's comparisons.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.count_sketch import cs_apply
from repro.core.hashes import ModeHash, fcs_sketch_len
from repro.core.sketches import fcs_cp, ts_cp


# ---------------------------------------------------------------------------
# T(u, u, u)
# ---------------------------------------------------------------------------


def fcs_tuuu(sk_T: jax.Array, u: jax.Array,
             hashes: Sequence[ModeHash]) -> jax.Array:
    """<FCS(T), FCS(u o u o u)> per repetition: (D,)."""
    lam = jnp.ones((1,), u.dtype)
    sk_u = fcs_cp(lam, [u[:, None]] * len(hashes), hashes)
    return jnp.sum(sk_T * sk_u, axis=-1)


def ts_tuuu(sk_T: jax.Array, u: jax.Array,
            hashes: Sequence[ModeHash]) -> jax.Array:
    lam = jnp.ones((1,), u.dtype)
    sk_u = ts_cp(lam, [u[:, None]] * len(hashes), hashes)
    return jnp.sum(sk_T * sk_u, axis=-1)


# ---------------------------------------------------------------------------
# T(I, u, u)
# ---------------------------------------------------------------------------


def fcs_tiuu(sk_T: jax.Array, u: jax.Array,
             hashes: Sequence[ModeHash]) -> jax.Array:
    """Eq. 17.  sk_T: (D, J~).  Returns per-repetition estimates (D, I_1)."""
    Jt = sk_T.shape[-1]
    mh1, mh2, mh3 = hashes
    cs2 = cs_apply(u, mh2)                       # (D, J2)
    cs3 = cs_apply(u, mh3)                       # (D, J3)
    f = (jnp.fft.rfft(sk_T, n=Jt, axis=-1)
         * jnp.conj(jnp.fft.rfft(cs2, n=Jt, axis=-1))
         * jnp.conj(jnp.fft.rfft(cs3, n=Jt, axis=-1)))
    z = jnp.fft.irfft(f, n=Jt, axis=-1)          # (D, J~)

    def one(zd, h, s):
        return s * zd[h]
    return jax.vmap(one)(z, mh1.h, mh1.s)        # (D, I1)


def ts_tiuu(sk_T: jax.Array, u: jax.Array,
            hashes: Sequence[ModeHash]) -> jax.Array:
    """TS analogue (Wang et al. 2015): circular correlation, mod-J lookup."""
    J = sk_T.shape[-1]
    mh1, mh2, mh3 = hashes
    cs2 = cs_apply(u, mh2)
    cs3 = cs_apply(u, mh3)
    f = (jnp.fft.rfft(sk_T, n=J, axis=-1)
         * jnp.conj(jnp.fft.rfft(cs2, n=J, axis=-1))
         * jnp.conj(jnp.fft.rfft(cs3, n=J, axis=-1)))
    z = jnp.fft.irfft(f, n=J, axis=-1)

    def one(zd, h, s):
        return s * zd[h % J]
    return jax.vmap(one)(z, mh1.h, mh1.s)


# ---------------------------------------------------------------------------
# Kronecker-product compression (Section 4.3.1)
# ---------------------------------------------------------------------------


def fcs_kron_compress(A: jax.Array, B: jax.Array,
                      hashes: Sequence[ModeHash]) -> jax.Array:
    """FCS(A (x) B) from the factors: convolve the two 2-mode FCS sketches.
    hashes = (h1..h4) for (rows(A), cols(A), rows(B), cols(B)).
    Returns (D, J~), J~ = sum J_n - 3."""
    from repro.core.sketches import fcs_general
    Jt = fcs_sketch_len([mh.J for mh in hashes])
    skA = fcs_general(A, hashes[:2])             # (D, J1+J2-1)
    skB = fcs_general(B, hashes[2:])             # (D, J3+J4-1)
    f = (jnp.fft.rfft(skA, n=Jt, axis=-1)
         * jnp.fft.rfft(skB, n=Jt, axis=-1))
    return jnp.fft.irfft(f, n=Jt, axis=-1)


def fcs_kron_decompress(sk: jax.Array, hashes: Sequence[ModeHash],
                        shapeA: Tuple[int, int], shapeB: Tuple[int, int]
                        ) -> jax.Array:
    """Median-of-D estimate of A (x) B (I1*I3, I2*I4)."""
    mh1, mh2, mh3, mh4 = hashes
    I1, I2 = shapeA
    I3, I4 = shapeB

    def one(d):
        pos = (mh1.h[d][:, None, None, None] + mh2.h[d][None, :, None, None]
               + mh3.h[d][None, None, :, None] + mh4.h[d][None, None, None, :])
        sign = (mh1.s[d][:, None, None, None] * mh2.s[d][None, :, None, None]
                * mh3.s[d][None, None, :, None] * mh4.s[d][None, None, None, :])
        est = sign * sk[d][pos]                  # (I1, I2, I3, I4)
        return est
    est = jax.lax.map(one, jnp.arange(mh1.D))
    est = jnp.median(est, axis=0)
    # (i1, i2, i3, i4) -> Kron layout (I3(i1-1)+i3, I4(i2-1)+i4)
    return est.transpose(0, 2, 1, 3).reshape(I1 * I3, I2 * I4)


# ---------------------------------------------------------------------------
# Mode-contraction compression (Section 4.3.2): A (I1,I2,L) x_3,1 B (L,I3,I4)
# ---------------------------------------------------------------------------


def fcs_contraction_compress(A: jax.Array, B: jax.Array,
                             hashes: Sequence[ModeHash],
                             l_chunk: int = 8) -> jax.Array:
    """FCS(A o_{3,1} B) = sum_l conv(FCS(A[:,:,l]), FCS(B[l])) — computed in
    the frequency domain with the sum over l inside (one irfft total)."""
    from repro.core.sketches import fcs_general
    Jt = fcs_sketch_len([mh.J for mh in hashes])
    L = A.shape[-1]

    def one_l(l):
        skA = fcs_general(A[:, :, l], hashes[:2])
        skB = fcs_general(B[l], hashes[2:])
        return (jnp.fft.rfft(skA, n=Jt, axis=-1)
                * jnp.fft.rfft(skB, n=Jt, axis=-1))

    f = jax.lax.map(one_l, jnp.arange(L)).sum(axis=0)
    return jnp.fft.irfft(f, n=Jt, axis=-1)


def fcs_contraction_decompress(sk: jax.Array, hashes: Sequence[ModeHash],
                               shape: Tuple[int, int, int, int]) -> jax.Array:
    """Median-of-D estimate of the (I1, I2, I3, I4) contraction result."""
    mh = hashes
    I1, I2, I3, I4 = shape

    def one(d):
        pos = (mh[0].h[d][:, None, None, None] + mh[1].h[d][None, :, None, None]
               + mh[2].h[d][None, None, :, None] + mh[3].h[d][None, None, None, :])
        sign = (mh[0].s[d][:, None, None, None] * mh[1].s[d][None, :, None, None]
                * mh[2].s[d][None, None, :, None] * mh[3].s[d][None, None, None, :])
        return sign * sk[d][pos]
    est = jax.lax.map(one, jnp.arange(mh[0].D))
    return jnp.median(est, axis=0)
