"""TS (Def. 2), HCS (Def. 3) and FCS (Def. 4) for general tensors and for
CP-form tensors (FFT fast paths).

Layout conventions: mode order follows tensor axes; all sketches carry a
leading D axis (independent repetitions, median-combined by estimators.py).

General-tensor path ("mode folding"): sketch mode n, then shift-accumulate
by h_n — O(nnz(T)) per mode without ever materializing the combined hash:

    TS : circular shifts, output length J (mod-J wraparound)
    FCS: linear shifts, output length J~ = sum J_n - N + 1 (no wraparound —
         the spatial offsets survive, which is exactly the paper's accuracy
         argument vs TS)
    HCS: independent per-mode CS -> (D, J_1, ..., J_N)

CP-form path (Eqs. 3, 5, 8): per-mode CS of the factor columns, then
FFT-domain products: circular J-point (TS) / zero-padded J~-point (FCS) /
materialized outer product (HCS — the expensive one, Eq. 5).
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.count_sketch import cs_apply_cols
from repro.core.hashes import ModeHash, fcs_sketch_len


# ---------------------------------------------------------------------------
# General tensors
# ---------------------------------------------------------------------------


def _sketch_general(T: jax.Array, hashes: Sequence[ModeHash],
                    circular: bool) -> jax.Array:
    """One flat scatter-add per repetition d: position(i_1..i_N) =
    sum_n h_n(i_n) (mod J for TS), sign = prod_n s_n(i_n).  The combined
    hash values are broadcast-computed on the fly — O(nnz(T)) work and no
    stored long hash pair.  lax.map over D keeps the index grid to one
    repetition at a time."""
    N = T.ndim
    D = hashes[0].D
    Js = [mh.J for mh in hashes]
    out_len = Js[0] if circular else fcs_sketch_len(Js)

    def one(d):
        pos = jnp.zeros((1,) * N, jnp.int32)
        sign = jnp.ones((1,) * N, T.dtype)
        for n, mh in enumerate(hashes):
            bshape = tuple(mh.I if m == n else 1 for m in range(N))
            pos = pos + mh.h[d].reshape(bshape)
            sign = sign * mh.s[d].reshape(bshape).astype(T.dtype)
        if circular:
            pos = pos % out_len
        flat = (sign * T).reshape(-1)
        return jnp.zeros((out_len,), T.dtype).at[pos.reshape(-1)].add(flat)

    return jax.lax.map(one, jnp.arange(D))


def ts_general(T: jax.Array, hashes: Sequence[ModeHash]) -> jax.Array:
    """Tensor Sketch of a dense tensor: (D, J)."""
    return _sketch_general(T, hashes, circular=True)


def fcs_general(T: jax.Array, hashes: Sequence[ModeHash]) -> jax.Array:
    """Fast Count Sketch of a dense tensor (Eq. 13): (D, J~)."""
    return _sketch_general(T, hashes, circular=False)


def hcs_general(T: jax.Array, hashes: Sequence[ModeHash]) -> jax.Array:
    """Higher-order Count Sketch (Eq. 4): (D, J_1, ..., J_N)."""
    D = hashes[0].D

    def one(d):
        out = T
        for n, mh in enumerate(hashes):
            onehot = (jax.nn.one_hot(mh.h[d], mh.J, dtype=T.dtype)
                      * mh.s[d][:, None].astype(T.dtype))
            out = jnp.moveaxis(jnp.tensordot(out, onehot, axes=([n], [0])),
                               -1, n)
        return out
    return jax.vmap(one)(jnp.arange(D))


# ---------------------------------------------------------------------------
# CP-form tensors  T = [[lambda; U^(1), ..., U^(N)]]
# ---------------------------------------------------------------------------


def _cs_factors(lam: jax.Array, Us: Sequence[jax.Array],
                hashes: Sequence[ModeHash]) -> Tuple[jax.Array, ...]:
    return tuple(cs_apply_cols(U, mh) for U, mh in zip(Us, hashes))


def ts_cp(lam: jax.Array, Us: Sequence[jax.Array],
          hashes: Sequence[ModeHash]) -> jax.Array:
    """Eq. 3: mode-J circular convolution via J-point FFT.  (D, J)."""
    J = hashes[0].J
    sketched = _cs_factors(lam, Us, hashes)         # each (D, J, R)
    f = jnp.fft.rfft(sketched[0], n=J, axis=1)
    for sk in sketched[1:]:
        f = f * jnp.fft.rfft(sk, n=J, axis=1)
    conv = jnp.fft.irfft(f, n=J, axis=1)            # (D, J, R)
    return jnp.einsum("djr,r->dj", conv, lam)


def fcs_cp(lam: jax.Array, Us: Sequence[jax.Array],
           hashes: Sequence[ModeHash]) -> jax.Array:
    """Eq. 8: zero-padded linear convolution via J~-point FFT.  (D, J~)."""
    Jt = fcs_sketch_len([mh.J for mh in hashes])
    sketched = _cs_factors(lam, Us, hashes)
    f = jnp.fft.rfft(sketched[0], n=Jt, axis=1)
    for sk in sketched[1:]:
        f = f * jnp.fft.rfft(sk, n=Jt, axis=1)
    conv = jnp.fft.irfft(f, n=Jt, axis=1)           # (D, J~, R)
    return jnp.einsum("djr,r->dj", conv, lam)


def hcs_cp(lam: jax.Array, Us: Sequence[jax.Array],
           hashes: Sequence[ModeHash]) -> jax.Array:
    """Eq. 5: materialized outer product of CS'd factors (the slow one —
    O(R * prod J_n)).  Supports N in {2, 3, 4}."""
    sketched = _cs_factors(lam, Us, hashes)
    N = len(sketched)
    if N == 2:
        return jnp.einsum("dar,dbr,r->dab", *sketched, lam)
    if N == 3:
        return jnp.einsum("dar,dbr,dcr,r->dabc", *sketched, lam)
    if N == 4:
        return jnp.einsum("dar,dbr,dcr,der,r->dabce", *sketched, lam)
    raise NotImplementedError(N)


# ---------------------------------------------------------------------------
# Decompression (FCS)
# ---------------------------------------------------------------------------


def fcs_decompress_entry(sk: jax.Array, hashes: Sequence[ModeHash],
                         idx: Sequence[jax.Array]) -> jax.Array:
    """Recover entries of the original tensor from an FCS sketch (paper
    Section 4.3 decompression rule).  ``idx``: one integer array per mode,
    broadcastable to the output shape.  Returns (D, ...) estimates (median
    over D is the caller's job so error-feedback schemes can see all D)."""
    D = hashes[0].D

    def one(d):
        pos = 0
        sign = 1.0
        for mh, ix in zip(hashes, idx):
            pos = pos + mh.h[d][ix]
            sign = sign * mh.s[d][ix]
        return sign * sk[d][pos]
    return jax.vmap(one)(jnp.arange(D))


def hcs_decompress_entry(sk: jax.Array, hashes: Sequence[ModeHash],
                         idx: Sequence[jax.Array]) -> jax.Array:
    """HCS decompression: element = prod s_n * HCS[h_1(i_1), ..., h_N(i_N)]."""
    D = hashes[0].D

    def one(d):
        sign = 1.0
        gathered = sk[d]
        for n, (mh, ix) in enumerate(zip(hashes, idx)):
            sign = sign * mh.s[d][ix]
        pos = tuple(mh.h[d][ix] for mh, ix in zip(hashes, idx))
        return sign * gathered[pos]
    return jax.vmap(one)(jnp.arange(D))
