"""Median-of-D combination (the paper computes D independent sketches and
returns the median for robustness, backed by Cor. 1's Chebyshev argument)."""
from __future__ import annotations

import jax.numpy as jnp


def median_combine(estimates, axis: int = 0):
    """Median over the D axis of per-repetition estimates."""
    return jnp.median(estimates, axis=axis)


def mean_combine(estimates, axis: int = 0):
    return jnp.mean(estimates, axis=axis)
