"""Core sketching library: CS / TS / HCS / FCS (the paper's contribution).

Public API:
  hashes       : make_mode_hash / make_tensor_hashes / fcs_sketch_len
  count_sketch : cs_apply / cs_apply_cols / cs_unsketch
  sketches     : {ts,fcs,hcs}_general, {ts,fcs,hcs}_cp, fcs_decompress_entry
  contraction  : fcs_tuuu / fcs_tiuu (+ts_*), kron + mode-contraction codecs
  estimators   : median_combine
"""
from repro.core.hashes import (  # noqa: F401
    ModeHash, fcs_sketch_len, make_mode_hash, make_tensor_hashes,
    storage_bytes_cs_long, storage_bytes_tabulated,
)
from repro.core.count_sketch import (  # noqa: F401
    cs_apply, cs_apply_batch, cs_apply_cols, cs_unsketch, cs_unsketch_at,
)
from repro.core.sketches import (  # noqa: F401
    fcs_cp, fcs_decompress_entry, fcs_general, hcs_cp, hcs_decompress_entry,
    hcs_general, ts_cp, ts_general,
)
from repro.core.contraction import (  # noqa: F401
    fcs_contraction_compress, fcs_contraction_decompress, fcs_kron_compress,
    fcs_kron_decompress, fcs_tiuu, fcs_tuuu, ts_tiuu, ts_tuuu,
)
from repro.core.estimators import median_combine, mean_combine  # noqa: F401
