"""2-wise independent hash families for sketching.

The paper's headline storage win: FCS/TS/HCS keep one short hash pair per
mode — O(sum I_n) — instead of CS's O(prod I_n) pair on vec(T).

We use the affine-mod-prime family h(i) = ((a*i + b) mod p) mod J with
p = 2^31 - 1 (Mersenne), which is 2-wise independent, so Prop. 1 / Cor. 1 of
the paper apply.  Each hash is stored BOTH as (a, b) coefficients (evaluated
on the fly inside Pallas kernels — 8 bytes instead of 4*I) and as a
tabulated int32 array (for gather/scatter formulations).  D independent
repetitions stack on a leading axis.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

PRIME = 2_147_483_647  # 2^31 - 1


class ModeHash(NamedTuple):
    """Hash pair (h: [I] -> [J], s: [I] -> {+-1}) x D repetitions."""
    h: jax.Array        # (D, I) int32 in [0, J)
    s: jax.Array        # (D, I) float32 in {+1, -1}
    coeffs: jax.Array   # (D, 4) uint64: (ah, bh, as_, bs)
    J: int

    @property
    def D(self) -> int:
        return self.h.shape[0]

    @property
    def I(self) -> int:
        return self.h.shape[1]


def make_mode_hash(key: jax.Array, I: int, J: int, D: int = 1) -> ModeHash:
    """Tables are generated host-side in numpy uint64 (jax x64 is off in
    this deployment; the affine products need 62 bits).  Pallas kernels that
    re-evaluate hashes on the fly use the 16-bit-split trick on ``coeffs``."""
    import numpy as np
    k1, k2, k3, k4 = jax.random.split(key, 4)
    ah = np.asarray(jax.random.randint(k1, (D,), 1, PRIME, jnp.int32),
                    np.uint64)
    bh = np.asarray(jax.random.randint(k2, (D,), 0, PRIME, jnp.int32),
                    np.uint64)
    as_ = np.asarray(jax.random.randint(k3, (D,), 1, PRIME, jnp.int32),
                     np.uint64)
    bs = np.asarray(jax.random.randint(k4, (D,), 0, PRIME, jnp.int32),
                    np.uint64)
    idx = np.arange(I, dtype=np.uint64)
    h = (((ah[:, None] * idx[None, :] + bh[:, None]) % PRIME) % J
         ).astype(np.int32)
    s = (1.0 - 2.0 * (((as_[:, None] * idx[None, :] + bs[:, None]) % PRIME)
                      % 2)).astype(np.float32)
    coeffs = np.stack([ah, bh, as_, bs], axis=-1).astype(np.int64)
    return ModeHash(h=jnp.asarray(h), s=jnp.asarray(s),
                    coeffs=jnp.asarray(coeffs.astype(np.float64) % 2**31,
                                       jnp.int32), J=J)


def make_tensor_hashes(key: jax.Array, dims: Sequence[int],
                       Js: Sequence[int] | int, D: int = 1
                       ) -> Tuple[ModeHash, ...]:
    """One ModeHash per tensor mode."""
    if isinstance(Js, int):
        Js = [Js] * len(dims)
    keys = jax.random.split(key, len(dims))
    return tuple(make_mode_hash(k, I, J, D)
                 for k, I, J in zip(keys, dims, Js))


def fcs_sketch_len(Js: Sequence[int]) -> int:
    """J~ = sum_n J_n - N + 1 (length of the linear-convolution sketch)."""
    return int(sum(Js) - len(Js) + 1)


def combined_fcs_hash(hashes: Sequence[ModeHash]) -> Tuple[jax.Array, jax.Array]:
    """Materialize the structured long pair (Eq. 7) on the full index grid
    (row-major / last mode fastest, matching ``T.reshape(-1)``) — ONLY for
    tests/small tensors; production code never builds this (that's the
    point of the paper)."""
    D = hashes[0].D
    N = len(hashes)
    h_tot: jax.Array = jnp.zeros((D,) + (1,) * N, jnp.int32)
    s_tot: jax.Array = jnp.ones((D,) + (1,) * N, jnp.float32)
    for n, mh in enumerate(hashes):
        bshape = (D,) + tuple(mh.I if m == n else 1 for m in range(N))
        h_tot = h_tot + mh.h.reshape(bshape)
        s_tot = s_tot * mh.s.reshape(bshape)
    return h_tot.reshape(D, -1), s_tot.reshape(D, -1)


def storage_bytes_tabulated(hashes: Sequence[ModeHash]) -> int:
    """Hash memory if stored as tables (paper's Figs. 5/6 metric)."""
    return sum(mh.h.size * 4 + mh.s.size * 4 for mh in hashes)


def storage_bytes_cs_long(dims: Sequence[int], D: int) -> int:
    """What CS on vec(T) would need: one pair of length prod(dims)."""
    n = 1
    for d in dims:
        n *= d
    return n * D * 8
