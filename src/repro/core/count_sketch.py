"""Count Sketch (Charikar et al. 2002) — Definition 1.

CS(x; h, s)_j = sum_{h(i)=j} s(i) x(i): a signed random projection computed
in O(nnz(x)) by scatter-add.  On TPU the scatter is reformulated as a blocked
signed-one-hot matmul (see repro.kernels.count_sketch); this module is the
jnp reference used everywhere correctness matters.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.hashes import ModeHash


def cs_apply(x: jax.Array, mh: ModeHash) -> jax.Array:
    """x: (I,) -> (D, J)."""
    def one(h, s):
        return jnp.zeros((mh.J,), x.dtype).at[h].add(s.astype(x.dtype) * x)
    return jax.vmap(one)(mh.h, mh.s)


def cs_apply_cols(X: jax.Array, mh: ModeHash) -> jax.Array:
    """Column-wise CS of a matrix: X (I, R) -> (D, J, R)."""
    def one(h, s):
        return jnp.zeros((mh.J, X.shape[1]), X.dtype).at[h].add(
            s[:, None].astype(X.dtype) * X)
    return jax.vmap(one)(mh.h, mh.s)


def cs_apply_batch(X: jax.Array, mh: ModeHash) -> jax.Array:
    """Row-batched CS: X (..., I) -> (D, ..., J)."""
    def one(h, s):
        sx = X * s.astype(X.dtype)
        out = jnp.zeros(X.shape[:-1] + (mh.J,), X.dtype)
        return out.at[..., h].add(sx)  # scatter along last axis

    # scatter with duplicate indices along the last axis: use one-hot matmul
    # for correctness (at[..., h] would not reduce duplicates the way we
    # want for all backends), J assumed modest here.
    def one_matmul(h, s):
        onehot = (jax.nn.one_hot(h, mh.J, dtype=X.dtype)
                  * s[:, None].astype(X.dtype))
        return X @ onehot
    return jax.vmap(one_matmul)(mh.h, mh.s)


def cs_unsketch(y: jax.Array, mh: ModeHash) -> jax.Array:
    """Decompress: x_hat(i) = median_d s_d(i) * y_d[h_d(i)].  y: (D, J) ->
    (I,) after the median over D."""
    def one(yd, h, s):
        return s * yd[h]
    est = jax.vmap(one)(y, mh.h, mh.s)          # (D, I)
    return jnp.median(est, axis=0)


def cs_unsketch_at(y: jax.Array, mh: ModeHash, idx: jax.Array) -> jax.Array:
    """Decompress selected indices only."""
    def one(yd, h, s):
        return s[idx] * yd[h[idx]]
    return jnp.median(jax.vmap(one)(y, mh.h, mh.s), axis=0)
