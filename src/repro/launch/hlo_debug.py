import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Debug helper: compile one cell and attribute HBM/collective traffic to
jax-level ops (via HLO metadata op_name), trip-count weighted."""
import argparse
import re
from collections import defaultdict

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPE_BY_NAME
from repro.configs.registry import get_config
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import build_param_pspecs, cache_pspecs, make_rules
from repro.models import model as M
from repro.models.sharding import logical_rules


def compile_cell(arch, shape_name, multi_pod=False):
    cfg = get_config(arch)
    shape = SHAPE_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules, strategy = make_rules(cfg, shape.kind, shape_name == "long_500k",
                                 multi_pod, shape.global_batch)
    specs = M.input_specs(cfg, shape)
    pspecs = M.param_specs(cfg)
    named = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                   is_leaf=lambda x: isinstance(x, P))
    param_sh = named(build_param_pspecs(cfg, pspecs, rules, strategy))
    with mesh, logical_rules(rules):
        if shape.kind == "train":
            fn = M.make_train_step(cfg)
            batch_sh = named(jax.tree.map(
                lambda x: P(rules["batch"], *([None] * (x.ndim - 1))),
                specs["batch"]))
            comp = jax.jit(fn, in_shardings=(param_sh, batch_sh),
                           out_shardings=(NamedSharding(mesh, P()), param_sh)
                           ).lower(pspecs, specs["batch"]).compile()
        elif shape.kind == "prefill":
            fn = M.make_prefill_step(cfg)
            batch_sh = named(jax.tree.map(
                lambda x: P(rules["batch"], *([None] * (x.ndim - 1))),
                specs["batch"]))
            comp = jax.jit(fn, in_shardings=(param_sh, batch_sh)
                           ).lower(pspecs, specs["batch"]).compile()
        else:
            fn = M.make_serve_step(cfg)
            cache_sh = named(cache_pspecs(cfg, specs["cache"], rules))
            comp = jax.jit(fn, in_shardings=(
                param_sh, cache_sh, NamedSharding(mesh, P(rules["batch"], None)),
                NamedSharding(mesh, P())), donate_argnums=(1,)).lower(
                pspecs, specs["cache"], specs["tokens"], specs["index"]
                ).compile()
    return comp


def attribute(hlo, top=25, what="hbm"):
    hc = H.HloCost(hlo)
    mult = {hc.entry: 1}
    changed = True
    while changed:
        changed = False
        for cname, instrs in hc.comps.items():
            base = mult.get(cname)
            if base is None:
                continue
            for ins in instrs:
                if ins.op == "while":
                    tgt = dict(re.findall(r"(condition|body)=%?([\w.\-]+)",
                                          ins.rest))
                    t = hc._trip_count(ins.rest, tgt.get("condition", ""))
                    b = tgt.get("body")
                    if b and mult.get(b, 0) < base * t:
                        mult[b] = base * t
                        changed = True
                elif ins.op in ("call", "fusion", "custom-call"):
                    m = re.search(r"(?:to_apply|calls)=%?([\w.\-]+)", ins.rest)
                    if m and mult.get(m.group(1), 0) < base:
                        mult[m.group(1)] = base
                        changed = True
    agg = defaultdict(float)
    rows = []
    for cname, instrs in hc.comps.items():
        f = mult.get(cname)
        if not f:
            continue
        for ins in instrs:
            md = re.search(r'op_name="([^"]+)"', ins.rest)
            name = md.group(1) if md else f"<{ins.op}>"
            bop = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if what == "coll":
                if bop not in ("all-reduce", "all-gather", "reduce-scatter",
                               "all-to-all", "collective-permute"):
                    continue
                _, ob = H._shape_elems_bytes(ins.type_str)
                val = H._collective_traffic(bop, ob, H._group_size(ins.rest)) * f
            else:
                if ins.op in H._SKIP_BYTES_OPS and ins.op not in ("fusion",
                                                                  "custom-call"):
                    continue
                _, ob = H._shape_elems_bytes(ins.type_str)
                opb = 0
                for on in hc._operand_names(ins.rest):
                    t = hc._types.get((cname, on))
                    if t:
                        opb += H._shape_elems_bytes(t)[1]
                val = (ob + opb) * f
            rows.append((val, f, ins.op, ins.type_str[:36], name[:100]))
            agg[name.split("/")[-1][:60]] += val
    rows.sort(reverse=True)
    for r in rows[:top]:
        print(f"{r[0]/2**30:9.2f}GiB x{r[1]:>5} {r[2]:14s} {r[3]:36s} {r[4]}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("--what", default="hbm", choices=["hbm", "coll"])
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()
    comp = compile_cell(args.arch, args.shape)
    attribute(comp.as_text(), top=args.top, what=args.what)
