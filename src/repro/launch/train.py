"""Training driver.

  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --steps 100 \
      --batch 8 --seq 128 [--reduced] [--grad-compression] \
      [--ckpt-dir /tmp/run1 --resume]

On a real TPU deployment this process runs per host under the production
mesh (launch/mesh.py); on this container it drives the same step functions
on the reduced configs.
"""
from __future__ import annotations

import argparse

from repro.configs.registry import ARCH_IDS, get_config, reduced_config
from repro.train.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="yi-9b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--opt-state-ratio", type=int, default=0,
                    help="> 0: sketch AdamW (m, v) moments at this "
                         "compression ratio (repro.sketch)")
    ap.add_argument("--opt-state-min-elems", type=int, default=None,
                    help="leaves smaller than this keep dense moments "
                         "(default: config value; lower it for reduced "
                         "configs, whose leaves are all < 64Ki)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--crash-at-step", type=int, default=None,
                    help="failure injection for FT tests")
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if args.opt_state_ratio:
        import dataclasses
        changes = {"opt_state_ratio": args.opt_state_ratio}
        if args.opt_state_min_elems is not None:
            changes["opt_state_min_elems"] = args.opt_state_min_elems
        cfg = dataclasses.replace(
            cfg, sketch=dataclasses.replace(cfg.sketch, **changes))
    hist = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                 lr=args.lr, seed=args.seed, ckpt_dir=args.ckpt_dir,
                 ckpt_every=args.ckpt_every, resume=args.resume,
                 grad_compression=args.grad_compression or None,
                 crash_at_step=args.crash_at_step)
    print(f"FINAL loss={hist.losses[-1]:.4f} steps={len(hist.losses)} "
          f"stragglers={len(hist.stragglers)}")


if __name__ == "__main__":
    main()
