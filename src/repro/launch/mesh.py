"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  The dry-run forces 512 host-platform devices
before importing jax; real deployments get the same mesh over TPU chips.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types; older releases are Auto-only
    from jax.sharding import AxisType
except ImportError:
    AxisType = None


def _mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(tuple(shape), tuple(axes))
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh(shape, axes):
    """Elastic variant: any (data, model) / (pod, data, model) shape, used by
    the trainer for small CPU runs and by elastic restarts."""
    return _mesh(shape, axes)
