"""Post-compile HLO analysis with while-loop trip-count weighting.

XLA's ``compiled.cost_analysis()`` visits each instruction ONCE — a
scan-over-layers body is counted a single time instead of num_layers times,
and collectives aren't counted at all.  This module walks the compiled HLO
text, builds a per-computation cost (flops / HBM bytes / collective bytes),
and multiplies while-loop bodies by their trip counts (parsed from the loop
condition's comparison constant).

Cost model:
  flops            : dot ops: 2 * prod(output dims) * prod(contracting dims)
  hbm bytes        : per (post-fusion) instruction: output bytes + operand
                     bytes, skipping pure metadata ops — i.e. fusion-boundary
                     traffic, the standard roofline proxy
  collective bytes : per-device traffic with ring-algorithm multipliers
                     (all-reduce 2x(g-1)/g, all-gather/all-to-all (g-1)/g on
                     the full buffer, reduce-scatter (g-1)x output,
                     collective-permute 1x)

Groups spanning > pod_size devices are attributed to DCN, else ICI.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "token": 0,
}

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+) = (.+?) ([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+) \(.*\{\s*$")
_CALL_TARGET_RE = re.compile(
    r"(?:condition|body|to_apply|calls)=%?([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{.*?\})\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_RE = re.compile(r"^(\d+)\)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "after-all", "iota", "while", "conditional", "call",
    "fusion", "custom-call", "get-dimension-size", "partition-id",
    "replica-id",
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    elems, total = 0, 0
    for dt, dims in _ARRAY_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


class _Instr:
    __slots__ = ("name", "type_str", "op", "rest")

    def __init__(self, name, type_str, op, rest):
        self.name, self.type_str, self.op, self.rest = name, type_str, op, rest


def _parse_computations(hlo: str) -> Dict[str, List[_Instr]]:
    comps: Dict[str, List[_Instr]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m and "{" in line:
                cur = m.group(1)
                comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, type_str, op, rest = m.groups()
            comps[cur].append(_Instr(name.lstrip("%"), type_str, op, rest))
    return comps


def _entry_name(hlo: str) -> Optional[str]:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
    return m.group(1) if m else None


_IOTA_FULL_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")


def _group_size(rest: str) -> int:
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(rest)
    if m:
        first = m.group(1).split("}")[0]
        return len(first.strip("{").split(","))
    return 1


def _pods_spanned(rest: str, pod_size: int) -> int:
    """How many pods a replica group spans (device ids are pod-major)."""
    import numpy as np
    m = _IOTA_FULL_RE.search(rest)
    if m:
        G, S = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            ids = ids.transpose([int(p) for p in m.group(4).split(",")])
        rows = ids.reshape(G, S) // pod_size
        return int(max(len(set(r.tolist())) for r in rows))
    m = _GROUPS_RE.search(rest)
    if m:
        first = m.group(1).split("}")[0]
        ids = [int(x) for x in first.strip("{").split(",") if x.strip()]
        return max(1, len({i // pod_size for i in ids}))
    return 1


def _collective_traffic(op: str, out_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * out_bytes * (g - 1) / g
    if op == "all-gather":
        return out_bytes * (g - 1) / g
    if op == "reduce-scatter":
        return float(out_bytes) * (g - 1)
    if op == "all-to-all":
        return out_bytes * (g - 1) / g
    return float(out_bytes)  # collective-permute


class HloCost:
    def __init__(self, hlo_text: str, pod_size: int = 256):
        self.comps = _parse_computations(hlo_text)
        self.entry = _entry_name(hlo_text)
        self.pod_size = pod_size
        self._types: Dict[Tuple[str, str], str] = {}
        self._producer: Dict[Tuple[str, str], _Instr] = {}
        for cname, instrs in self.comps.items():
            for ins in instrs:
                self._types[(cname, ins.name)] = ins.type_str
                self._producer[(cname, ins.name)] = ins
        self._memo: Dict[str, Dict[str, float]] = {}

    # -- helpers ----------------------------------------------------------
    def _operand_names(self, rest: str) -> List[str]:
        # operands appear before the first "), " attr separator
        depth, i = 1, 0
        while i < len(rest) and depth > 0:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        args = rest[:i - 1] if i else rest
        return [t.lstrip("%") for t in re.findall(r"%([\w.\-]+)", args)]

    def _trip_count(self, rest: str, cond_name: str) -> int:
        m = _TRIP_RE.search(rest)      # XLA annotates known trip counts
        if m:
            return int(m.group(1))
        consts = []                    # fallback: max constant in the cond
        for ins in self.comps.get(cond_name, []):
            if ins.op == "constant":
                mm = _CONST_RE.match(ins.rest)
                if mm:
                    consts.append(int(mm.group(1)))
        return max(consts) if consts else 1

    def _dot_flops(self, cname: str, ins: _Instr) -> float:
        out_elems, _ = _shape_elems_bytes(ins.type_str)
        m = _CONTRACT_RE.search(ins.rest)
        contract = 1
        ops = self._operand_names(ins.rest)
        if m and ops:
            lhs_type = self._types.get((cname, ops[0]), "")
            am = _ARRAY_RE.search(lhs_type)
            if am:
                dims = [int(d) for d in am.group(2).split(",") if d]
                idxs = [int(i) for i in m.group(1).split(",") if i]
                for i in idxs:
                    if i < len(dims):
                        contract *= dims[i]
        return 2.0 * out_elems * contract

    def _fusion_bytes(self, ins: _Instr) -> float:
        """Fusion boundary traffic with dynamic-slice awareness: a parameter
        consumed only by dynamic-slice ops is charged the slice size (scan
        bodies slice one layer/timestep from stacked arrays); an output
        produced by dynamic-update-slice is charged the update size (XLA
        updates the big buffer in place inside loops)."""
        m = re.search(r"calls=%?([\w.\-]+)", ins.rest)
        _, out_bytes = _shape_elems_bytes(ins.type_str)
        if not m or m.group(1) not in self.comps:
            return float(out_bytes)
        inner = self.comps[m.group(1)]
        uses: Dict[str, List[_Instr]] = {}
        for sub in inner:
            for on in self._operand_names(sub.rest):
                uses.setdefault(on, []).append(sub)
        total = 0.0
        root_dus = None
        for sub in inner:
            if sub.op == "parameter":
                u = uses.get(sub.name, [])
                if u and all(x.op == "dynamic-slice" for x in u):
                    total += max(_shape_elems_bytes(x.type_str)[1] for x in u)
                elif u and any(x.op == "dynamic-update-slice" for x in u):
                    # big accumulator updated in place: charge the update
                    dus = [x for x in u if x.op == "dynamic-update-slice"][0]
                    ops_d = self._operand_names(dus.rest)
                    if ops_d and ops_d[0] == sub.name and len(ops_d) > 1:
                        t = None
                        for s2 in inner:
                            if s2.name == ops_d[1]:
                                t = s2.type_str
                        upd = _shape_elems_bytes(t)[1] if t else \
                            _shape_elems_bytes(sub.type_str)[1]
                        total += 2.0 * upd      # read + write of the region
                        root_dus = sub.name
                    else:
                        total += _shape_elems_bytes(sub.type_str)[1]
                else:
                    total += _shape_elems_bytes(sub.type_str)[1]
        if root_dus is None:
            total += out_bytes
        return total

    # -- main walk ---------------------------------------------------------
    def comp_cost(self, cname: str) -> Dict[str, float]:
        if cname in self._memo:
            return self._memo[cname]
        total = {"flops": 0.0, "hbm_bytes": 0.0, "hbm_bytes_opt": 0.0,
                 "ici_bytes": 0.0, "dcn_bytes": 0.0}
        per_op: Dict[str, float] = {}
        self._memo[cname] = total  # cycle guard
        for ins in self.comps.get(cname, []):
            op = ins.op
            _, out_bytes = _shape_elems_bytes(ins.type_str)
            if op == "dot":
                total["flops"] += self._dot_flops(cname, ins)
            base_op = op[:-6] if op.endswith("-start") else op
            if base_op in _COLLECTIVES:
                g = _group_size(ins.rest)
                traffic = _collective_traffic(base_op, out_bytes, g)
                pods = _pods_spanned(ins.rest, self.pod_size) if g > 1 else 1
                # XLA:CPU upcasts bf16 dot operands to f32; a TPU build
                # moves these buffers at bf16.  If this collective's operand
                # chain is a bf16->f32 convert, charge bf16 bytes.
                if "f32[" in ins.type_str:
                    opsn = self._operand_names(ins.rest)
                    prod = self._producer.get((cname, opsn[0])) if opsn else None
                    for _hop in range(3):
                        if prod is None:
                            break
                        if prod.op in ("convert", "copy", "reshape",
                                       "transpose", "bitcast"):
                            src = self._operand_names(prod.rest)
                            st = self._types.get((cname, src[0])) if src else None
                            if prod.op == "convert" and st and "bf16[" in st:
                                traffic *= 0.5
                                break
                            prod = self._producer.get((cname, src[0])) \
                                if src else None
                        else:
                            break
                total["ici_bytes"] += traffic
                if pods > 1:
                    # hierarchical model: reduce-scatter within pod (ICI),
                    # then the per-device slice crosses the DCN
                    L = max(1, g // pods)
                    total["dcn_bytes"] += (2.0 * out_bytes * (pods - 1)
                                           / pods / L)
                per_op[base_op] = per_op.get(base_op, 0.0) + traffic
                per_op[base_op + "_count"] = per_op.get(base_op + "_count", 0) + 1
            if op == "while":
                tgt = dict(re.findall(r"(condition|body)=%?([\w.\-]+)",
                                      ins.rest))
                trips = self._trip_count(ins.rest, tgt.get("condition", ""))
                sub = self.comp_cost(tgt.get("body", ""))
                for k in total:
                    if k != "per_op":
                        total[k] += trips * sub[k]
                for k, v in sub.get("per_op", {}).items():
                    per_op[k] = per_op.get(k, 0.0) + trips * v
                continue
            if op in ("call", "fusion", "custom-call", "async-start"):
                m = re.search(r"(?:to_apply|calls)=%?([\w.\-]+)", ins.rest)
                if m and m.group(1) in self.comps:
                    sub = self.comp_cost(m.group(1))
                    total["flops"] += sub["flops"]
                    total["ici_bytes"] += sub["ici_bytes"]
                    total["dcn_bytes"] += sub["dcn_bytes"]
                    # bytes: fusion boundary only (operands+output below)
                    if op == "call":
                        total["hbm_bytes"] += sub["hbm_bytes"]
                    for k, v in sub.get("per_op", {}).items():
                        per_op[k] = per_op.get(k, 0.0) + v
            if op == "conditional":
                for t in re.findall(r"branch_computations=\{([^}]*)\}",
                                    ins.rest):
                    subs = [self.comp_cost(x.strip().lstrip("%"))
                            for x in t.split(",")]
                    if subs:
                        for k in ("flops", "hbm_bytes", "ici_bytes",
                                  "dcn_bytes"):
                            total[k] += max(s[k] for s in subs)
                m = re.search(r"true_computation=%?([\w.\-]+)", ins.rest)
                if m:
                    for key in ("true_computation", "false_computation"):
                        mm = re.search(key + r"=%?([\w.\-]+)", ins.rest)
                        if mm:
                            sub = self.comp_cost(mm.group(1))
                            for k in ("flops", "ici_bytes", "dcn_bytes"):
                                total[k] += sub[k]
            # HBM traffic at fusion/instruction boundary.  Two bounds:
            # pessimistic = every (post-CPU-fusion) instruction's IO;
            # optimistic = only ops a TPU pipeline cannot fuse away
            # (dots, fusions, reduces, scatter/gather, collectives) —
            # standalone elementwise/copy/transpose chains are assumed
            # fused on TPU.  Truth lies between; both are reported.
            if op == "fusion":
                fb = self._fusion_bytes(ins)
                total["hbm_bytes"] += fb
                total["hbm_bytes_opt"] += fb
            elif op not in _SKIP_BYTES_OPS:
                opb = 0
                for on in self._operand_names(ins.rest):
                    t = self._types.get((cname, on))
                    if t:
                        opb += _shape_elems_bytes(t)[1]
                total["hbm_bytes"] += out_bytes + opb
                if op in ("dot", "convolution", "reduce", "scatter",
                          "gather", "dynamic-slice", "dynamic-update-slice",
                          "sort", "rng", "cholesky", "fft",
                          "triangular-solve") or op in _COLLECTIVES \
                        or op.endswith("-start"):
                    total["hbm_bytes_opt"] += out_bytes + opb
        total["per_op"] = per_op
        self._memo[cname] = total
        return total

    def entry_cost(self) -> Dict[str, float]:
        if not self.entry:
            return {"flops": 0.0, "hbm_bytes": 0.0, "ici_bytes": 0.0,
                    "dcn_bytes": 0.0, "per_op": {}}
        return self.comp_cost(self.entry)


def analyze(hlo_text: str, pod_size: int = 256) -> Dict[str, float]:
    return HloCost(hlo_text, pod_size).entry_cost()


# ---------------------------------------------------------------------------
# Roofline terms (TPU v5e)
# ---------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (per chip)
DCN_BW = 6.25e9                 # bytes/s per chip across pods (~50 Gbit)


def roofline_terms(flops_per_device: float, hbm_bytes_per_device: float,
                   coll: Dict[str, float]) -> Dict[str, float]:
    t_compute = flops_per_device / PEAK_FLOPS_BF16
    t_memory = hbm_bytes_per_device / HBM_BW
    t_ici = coll.get("ici_bytes", 0.0) / ICI_BW
    t_dcn = coll.get("dcn_bytes", 0.0) / DCN_BW
    t_coll = t_ici + t_dcn
    dom = max(("compute", t_compute), ("memory", t_memory),
              ("collective", t_coll), key=lambda kv: kv[1])[0]
    bound = max(t_compute, t_memory, t_coll)
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "t_ici_s": t_ici,
        "t_dcn_s": t_dcn,
        "dominant": dom,
        "step_lower_bound_s": bound,
        "compute_roofline_fraction": t_compute / bound if bound else 0.0,
    }
