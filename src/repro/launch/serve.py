"""Serving driver: a mixed-length request stream through the
continuous-batching engine, with prefix-cache hit stats.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b \
      --requests 24 --max-new 16

The stream mimics production traffic: a handful of shared "system prompt"
prefixes with random per-request tails of mixed lengths, so the count-min
admission filter has real heavy hitters to find.  By default requests are
served closed-batch (submit all, drain); ``--arrival-rate R`` switches to
an OPEN-LOOP Poisson arrival process through the async front-end
(serve/frontend.py): requests arrive at R req/s on average, tokens
stream back per decode chunk, ``--cancel-frac`` hangs up a fraction of
clients mid-stream, and ``--deadline-s`` arms a per-request SLO (expired
requests surface partial output).  Either way the driver exits with the
engine's unified ``EngineStats`` snapshot.  Every family rides the
slot scheduler — attention families through chunked prefill + the prefix
cache, recurrent families (ssm/hybrid) through slot-inserted state.  Part
of the stream can be sampled (``--sampled-frac``) to exercise mixed
greedy/sampled decoding in the one compiled chunk, and ``--spec-k`` turns
on speculative decoding (a truncated / count-sketch-compressed draft
proposes, the target verifies in one multi-query step; acceptance rate
and mean accepted-run length are reported).  ``--kv-sketch-window N``
turns on sketched long-context KV: each slot keeps the most recent N
rows exact and folds older blocks into per-slot FCS tail tables
(``--long-context S`` appends one S-token demo prompt; the exact-window
vs sketched-tail byte split is printed).  ``--trace-out trace.json``
records the full request lifecycle + pump phases as Chrome trace-event
JSON (load in Perfetto), ``--metrics-jsonl metrics.jsonl`` streams
windowed metrics snapshots, and ``--fidelity-every N`` samples the
sketch-fidelity probe for folded slots every N decode rounds.  Runs on
the reduced config by default; pass ``--full`` for the full
architecture.
"""
from __future__ import annotations

import argparse
import asyncio
import dataclasses
import time

import jax
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config, reduced_config
from repro.models import model as M
from repro.obs import ServeObserver, Tracer
from repro.serve.frontend import AsyncServeEngine
from repro.serve.scheduler import KV_FAMILIES, Request, SlotScheduler


def make_request_stream(cfg, rng: np.random.RandomState, n_requests: int,
                        n_prefixes: int, prefix_len: int, max_tail: int,
                        max_new: int, rid0: int = 0,
                        sampled_frac: float = 0.0, temperature: float = 0.8,
                        top_k: int = 8):
    """Mixed-length prompts: each request samples one of ``n_prefixes``
    shared system prefixes and appends a random-length random tail; a
    ``sampled_frac`` fraction of requests asks for seeded top-k sampling
    instead of greedy decoding.  The canonical heavy-tailed workload
    generator — the CLI driver and benchmarks/bench_serve.py both use it."""
    prefixes = rng.randint(0, cfg.vocab_size,
                           (n_prefixes, prefix_len)).astype(np.int32)
    reqs = []
    for i in range(n_requests):
        p = prefixes[rng.randint(n_prefixes)]
        tail = rng.randint(0, cfg.vocab_size,
                           size=rng.randint(1, max_tail + 1)).astype(np.int32)
        sampled = rng.rand() < sampled_frac
        reqs.append(Request(
            rid=rid0 + i, tokens=np.concatenate([p, tail]), max_new=max_new,
            temperature=temperature if sampled else 0.0,
            top_k=top_k if sampled else 0,
            seed=int(rng.randint(1 << 30)) if sampled else None))
    return reqs


async def stream_poisson(front: AsyncServeEngine, reqs, rate: float,
                         cancel_frac: float, deadline_s: float,
                         rng: np.random.RandomState,
                         priority_frac: float = 0.0):
    """Open-loop Poisson driver: submit ``reqs`` with exponential
    inter-arrival gaps (mean 1/rate s), stream every response, and hang
    up on a ``cancel_frac`` fraction of clients midway through their
    budget.  A ``priority_frac`` fraction of requests submits at
    priority 1 — under slot pressure those preempt running priority-0
    requests, so traces show preempt + re-admission continuations.
    Returns (completions, first_token_latencies) — arrival pacing is
    wall-clock real, so TTFT numbers here include genuine queueing
    delay, not just compute."""
    results = []
    ttfts = []

    async def consume(handle, t_submit, cancel_after):
        n = 0
        async for _tok in handle.stream():
            if n == 0:
                ttfts.append(time.monotonic() - t_submit)
            n += 1
            if cancel_after is not None and n >= cancel_after:
                handle.cancel()
        results.append(handle.completion)

    tasks = []
    for r in reqs:
        h = await front.submit(
            r.tokens, max_new=r.max_new, temperature=r.temperature,
            top_k=r.top_k, seed=r.seed,
            priority=(1 if rng.rand() < priority_frac else 0),
            deadline_s=(deadline_s if deadline_s > 0 else 0),
            rid=r.rid)
        cancel_after = (max(1, r.max_new // 2)
                        if rng.rand() < cancel_frac else None)
        tasks.append(asyncio.ensure_future(
            consume(h, time.monotonic(), cancel_after)))
        if rate > 0:
            await asyncio.sleep(float(rng.exponential(1.0 / rate)))
    await asyncio.gather(*tasks)
    return results, ttfts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma-2b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--prefixes", type=int, default=3,
                    help="distinct shared system prefixes in the stream")
    ap.add_argument("--prefix-len", type=int, default=32)
    ap.add_argument("--max-tail", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--admit-threshold", type=int, default=2)
    ap.add_argument("--sampled-frac", type=float, default=0.25,
                    help="fraction of requests decoded with top-k sampling")
    ap.add_argument("--temperature", type=float, default=0.8,
                    help="temperature for the sampled fraction")
    ap.add_argument("--top-k", type=int, default=8,
                    help="top-k cutoff for the sampled fraction")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative draft tokens per verify round "
                         "(0 = plain decode; attention families only)")
    ap.add_argument("--draft-depth", type=int, default=1,
                    help="layers kept in the derived draft proposer")
    ap.add_argument("--draft-sketch-ratio", type=int, default=0,
                    help="count-sketch-compress the draft weights at this "
                         "ratio (0 = dense truncated draft)")
    ap.add_argument("--kv-sketch-window", type=int, default=0,
                    help="exact recent-window rows per slot; older blocks "
                         "fold into per-slot FCS tail tables and free "
                         "(0 = whole context exact; attention families)")
    ap.add_argument("--kv-sketch-ratio", type=int, default=8,
                    help="seq-axis compression of the tail tables "
                         "(cols ~ max_seq / ratio)")
    ap.add_argument("--long-context", type=int, default=0,
                    help="append one demo request with a prompt of this "
                         "many tokens (exercises fold-through prefill "
                         "and two-span decode; needs --kv-sketch-window)")
    ap.add_argument("--paged-kernels", choices=["auto", "on", "off"],
                    default="auto",
                    help="Pallas flash-decode paged attention on the serve "
                         "path (auto = TPU only; 'on' forces the kernels — "
                         "interpret mode on CPU, slow but exact)")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="open-loop Poisson arrivals at this rate "
                         "(req/s) through the async front-end; 0 = "
                         "closed-batch (submit all, drain)")
    ap.add_argument("--cancel-frac", type=float, default=0.0,
                    help="fraction of streamed clients that hang up "
                         "halfway through their budget (open-loop only)")
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help="per-request SLO deadline in seconds; expired "
                         "requests surface partial output (open-loop "
                         "only; 0 = none)")
    ap.add_argument("--priority-frac", type=float, default=0.0,
                    help="fraction of open-loop requests submitted at "
                         "priority 1 (may preempt running priority-0 "
                         "requests under slot pressure)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace-event JSON (load in "
                         "Perfetto / chrome://tracing) of the request "
                         "lifecycle and pump phases to this path")
    ap.add_argument("--trace-sample", type=float, default=1.0,
                    help="fraction of requests traced (deterministic "
                         "by rid); engine-level events always record")
    ap.add_argument("--metrics-jsonl", default=None,
                    help="append windowed metrics snapshots (counter "
                         "deltas/rates, latency quantiles, gauges) as "
                         "JSON lines to this path")
    ap.add_argument("--metrics-interval", type=float, default=0.5,
                    help="seconds between metrics windows")
    ap.add_argument("--fidelity-every", type=int, default=2,
                    help="sketch-fidelity probe cadence in decode "
                         "rounds (0 = off; needs --kv-sketch-window; "
                         "runs only at chunk boundaries)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true",
                    help="run the full architecture (default: reduced)")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else reduced_config(args.arch)
    # independent keys: reusing the params-init key for prompt generation
    # correlates weights with data (and made every run's prompts identical
    # to its init) — split once, use each stream exactly once.
    k_params, _ = jax.random.split(jax.random.PRNGKey(args.seed))
    params = M.init_params(k_params, cfg)
    serve = dataclasses.replace(
        cfg.serve, max_batch=args.max_batch, max_seq=args.max_seq,
        admit_threshold=args.admit_threshold, prefix_block=args.prefix_len,
        spec_k=args.spec_k, draft_depth=args.draft_depth,
        draft_sketch_ratio=args.draft_sketch_ratio,
        kv_sketch_window=args.kv_sketch_window,
        kv_sketch_ratio=args.kv_sketch_ratio,
        paged_kernels={"auto": None, "on": True, "off": False}[
            args.paged_kernels])
    if args.spec_k and cfg.family not in KV_FAMILIES:
        print(f"note: --spec-k needs an attention family; {cfg.family!r} "
              f"decodes plainly")
    sched = SlotScheduler(cfg, params, serve=serve)
    obs = None
    if args.trace_out or args.metrics_jsonl:
        obs = ServeObserver(
            tracer=(Tracer(sample_rate=args.trace_sample)
                    if args.trace_out else None),
            metrics_path=args.metrics_jsonl,
            metrics_interval=args.metrics_interval,
            fidelity_every=(args.fidelity_every
                            if args.kv_sketch_window > 0 else 0))
        sched.set_observer(obs)
    reqs = make_request_stream(cfg, np.random.RandomState(args.seed + 1),
                               args.requests, args.prefixes,
                               args.prefix_len, args.max_tail, args.max_new,
                               sampled_frac=args.sampled_frac,
                               temperature=args.temperature,
                               top_k=args.top_k)
    if args.long_context:
        assert args.kv_sketch_window > 0, "--long-context needs a window"
        S = min(args.long_context, args.max_seq - args.max_new)
        rng_lc = np.random.RandomState(args.seed + 2)
        reqs.append(Request(
            rid=len(reqs),
            tokens=rng_lc.randint(0, cfg.vocab_size, (S,)).astype(np.int32),
            max_new=args.max_new))

    t0 = time.time()
    if args.arrival_rate > 0:
        front = AsyncServeEngine(scheduler=sched)
        done, ttfts = asyncio.run(stream_poisson(
            front, reqs, args.arrival_rate, args.cancel_frac,
            args.deadline_s, np.random.RandomState(args.seed + 3),
            priority_frac=args.priority_frac))
    else:
        done = sched.run(reqs)
        ttfts = []
    dt = time.time() - t0
    toks = sum(len(c.tokens) for c in done)
    n_sampled = sum(1 for r in reqs if (r.temperature or 0) > 0)
    print(f"served {len(done)} requests ({n_sampled} sampled) / {toks} "
          f"tokens in {dt:.2f}s ({toks/dt:.1f} tok/s incl. compile)")
    if ttfts:
        print(f"open loop: arrival_rate={args.arrival_rate}/s, "
              f"ttft p50={np.percentile(ttfts, 50)*1e3:.0f}ms "
              f"p99={np.percentile(ttfts, 99)*1e3:.0f}ms "
              f"over {len(ttfts)} first tokens")
    if cfg.family in KV_FAMILIES:
        print(f"paged attention: "
              f"{'pallas kernels' if sched.use_kernels else 'jnp'} "
              f"(--paged-kernels {args.paged_kernels})")
        if sched.sketch_on:
            print(f"kv sketch: window={serve.kv_sketch_window} rows "
                  f"(ratio={serve.kv_sketch_ratio}, "
                  f"rows={serve.kv_sketch_rows}, "
                  f"cols={sched.tail_cols}) — exact-window "
                  f"{sched.kv_sketch_exact_bytes()}B live + sketched-tail "
                  f"{sched.kv_sketch_tail_bytes()}B fixed")
    else:
        print(f"recurrent family ({cfg.family}): slot-scheduled state, "
              f"prefix cache n/a")
    # the unified observability snapshot — queue/slots, pool occupancy,
    # prefix-cache hit rate, fold counts, speculative acceptance
    print(sched.stats().format())
    print("first completions:",
          [(c.rid, c.status, c.tokens[:6].tolist()) for c in done[:2]])
    if obs is not None:
        obs.close(stats=sched.stats(), trace_path=args.trace_out)
        if args.trace_out:
            n_ev = len(obs.tracer)
            print(f"trace: {n_ev} events -> {args.trace_out} "
                  f"(open in https://ui.perfetto.dev)")
        if args.metrics_jsonl:
            print(f"metrics: {len(obs.windows)} windows -> "
                  f"{args.metrics_jsonl}")


if __name__ == "__main__":
    main()
