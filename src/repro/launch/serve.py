"""Serving driver: batched greedy decoding on a reduced config.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --batch 4 \
      --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_config, reduced_config
from repro.models import model as M
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    engine = ServeEngine(cfg, params,
                         max_seq=args.prompt_len + args.max_new + 8)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    res = engine.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    toks = args.batch * args.max_new
    print(f"generated {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. compile)")
    print("first sequences:", res.tokens[:2, :8].tolist())


if __name__ == "__main__":
    main()
