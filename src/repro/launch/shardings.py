"""PartitionSpec builders for parameter and cache pytrees + per-arch rules.

Two parameter-placement strategies:

  fsdp : every leaf >= 1 MiB shards its largest evenly-divisible dim over
         ("data", "model") (256-way; pod-replicated on the multi-pod mesh —
         the cross-pod gradient all-reduce is the FCS-compression target).
         Used for train/prefill of every arch except xLSTM-tp cases.
         DeepSeek's 64 experts overlay expert-parallelism: E over "model",
         second dim over "data".
  tp   : name-based tensor-parallel map (weight-stationary decode for every
         arch, and xLSTM train-multi-pod/prefill where context sharding
         would gather full-width mLSTM KV).

jit input shardings must divide dims evenly; activation-level constraints
(which may be uneven) live in the model code via repro.models.sharding.shard.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.sharding import MeshAxes, train_rules, decode_rules

MODEL_AXIS = 16
DATA_AXIS = 16


def _last(path) -> str:
    k = path[-1]
    return getattr(k, "key", getattr(k, "name", str(k)))


def _in_blocks(path) -> bool:
    return any(getattr(k, "key", None) == "blocks" for k in path)


# ---------------------------------------------------------------------------
# Strategy selection
# ---------------------------------------------------------------------------


def select_strategy(cfg: ModelConfig, kind: str, multi_pod: bool,
                    global_batch: int = 0) -> str:
    if kind == "decode":
        return "tp"
    if cfg.family == "ssm":
        # xLSTM: mLSTM KV is full-width, so context (seq) sharding would
        # all-gather dm-wide tensors -> never fsdp_seq.  When the global
        # batch covers the whole mesh (train_4k single-pod: 256 = 16x16),
        # pure batch sharding with FSDP params eliminates the TP boundary
        # collectives entirely; the sLSTM recurrent-weight HBM traffic per
        # device is batch-size-independent at fixed global batch, so the
        # memory term is unchanged (hillclimb B, EXPERIMENTS.md section
        # Perf).  Otherwise (multi-pod train: 256 < 512; prefill: 32) fall
        # back to inner-dim TP.
        if kind == "train" and not multi_pod and global_batch \
                and global_batch % (DATA_AXIS * MODEL_AXIS) == 0:
            return "fsdp_batch"
        return "tp"
    return "fsdp_seq"


def make_rules(cfg: ModelConfig, kind: str, long_context: bool,
               multi_pod: bool, global_batch: int = 0
               ) -> Tuple[Dict[str, MeshAxes], str]:
    ep = bool(cfg.moe) and cfg.moe.num_experts % MODEL_AXIS == 0
    if kind == "decode":
        return decode_rules(multi_pod, long_context), "tp"
    strategy = select_strategy(cfg, kind, multi_pod, global_batch)
    rules = train_rules(multi_pod, strategy=strategy, expert_parallel=ep)
    if strategy == "tp" and cfg.num_heads < MODEL_AXIS:
        rules["heads"] = None
    return rules, strategy


# ---------------------------------------------------------------------------
# FSDP parameter specs
# ---------------------------------------------------------------------------

_FSDP_MIN_BYTES = 1 << 20


def _fsdp_leaf_spec(path, leaf, cfg: ModelConfig) -> P:
    name = _last(path)
    stacked = 1 if _in_blocks(path) else 0
    shape = leaf.shape[stacked:]
    nbytes = leaf.size * np.dtype(leaf.dtype).itemsize
    ep = bool(cfg.moe) and cfg.moe.num_experts % MODEL_AXIS == 0
    spec = [None] * len(shape)
    if name.startswith("r") and len(shape) == 3 and shape[0] <= 64:
        # sLSTM recurrent gate matrices (H, hd, hd): used INSIDE the
        # per-timestep scan — sharding them inserts a collective every
        # timestep.  They're ~4 MB each: replicate; their grad all-reduce
        # happens once per step.
        return P(*([None] * (stacked + len(shape))))
    if ep and name in ("we_gate", "we_up", "we_down"):
        # expert parallel: E over model; FSDP the next divisible dim on data
        spec[0] = "model"
        for i in range(1, len(shape)):
            if shape[i] % DATA_AXIS == 0:
                spec[i] = "data"
                break
    elif nbytes >= _FSDP_MIN_BYTES:
        # largest dim divisible by 256 over both axes, else by 16 over data
        cands = [(shape[i], i) for i in range(len(shape))
                 if shape[i] % (DATA_AXIS * MODEL_AXIS) == 0]
        if cands:
            _, i = max(cands)
            spec[i] = ("data", "model")
        else:
            cands = [(shape[i], i) for i in range(len(shape))
                     if shape[i] % DATA_AXIS == 0]
            if cands:
                _, i = max(cands)
                spec[i] = ("data",)
    return P(*([None] * stacked + spec))


# ---------------------------------------------------------------------------
# TP parameter specs (name-based)
# ---------------------------------------------------------------------------


def _tp_leaf_spec(path, leaf, cfg: ModelConfig, rules) -> P:
    name = _last(path)
    nd = leaf.ndim
    stacked = 1 if _in_blocks(path) else 0
    m = "model"
    v = "model"
    E = cfg.moe.num_experts if cfg.moe else 0
    expert_parallel = E > 0 and E % MODEL_AXIS == 0
    base: Any = None
    inner = nd - stacked
    if name == "embed":
        base = P(v, None)
    elif name == "head":
        base = P(None, v)
    elif name in ("wq", "wk", "wv", "w_gate", "w_up", "ws_gate", "ws_up",
                  "wz", "wx", "wxb", "wzb", "w_up_g", "wdt"):
        base = P(None, m)
    elif name in ("wi", "wf") and inner == 2 and leaf.shape[-1] > 64:
        base = P(None, m)            # sLSTM gates (d,d); mLSTM (d,H) tiny
    elif name in ("wo", "w_down", "ws_down", "out_proj"):
        base = P(m, None)
    elif name in ("bq", "bk", "bv", "conv_bx", "conv_b"):
        base = P(m)
    elif name in ("we_gate", "we_up"):
        base = P(m, None, None) if expert_parallel else P(None, None, m)
    elif name == "we_down":
        base = P(m, None, None) if expert_parallel else P(None, m, None)
    elif name in ("conv_wx", "conv_w"):
        base = P(None, m)
    elif name in ("dt_bias", "A_log", "D") and inner == 1:
        base = P(m)                  # mamba per-head params (H % 16 == 0)
    elif name == "norm" and inner == 1 and leaf.shape[-1] % MODEL_AXIS == 0 \
            and leaf.shape[-1] > cfg.d_model:
        base = P(m)                  # inner-dim gated norms (mamba/mlstm)
    elif name.startswith("r") and inner == 3:
        base = P(None, None, m)      # sLSTM recurrent (H, hd, hd)
    if base is None:
        base = P(*([None] * inner))
    if stacked:
        base = P(None, *base)
    if len(base) != nd:
        base = P(*(list(base) + [None] * (nd - len(base))))
    return base


def build_param_pspecs(cfg: ModelConfig, params_tree, rules,
                       strategy: str) -> Any:
    if strategy in ("fsdp_seq", "fsdp_batch"):
        return jax.tree_util.tree_map_with_path(
            lambda p, l: _fsdp_leaf_spec(p, l, cfg), params_tree)
    return jax.tree_util.tree_map_with_path(
        lambda p, l: _tp_leaf_spec(p, l, cfg, rules), params_tree)


# ---------------------------------------------------------------------------
# Optimizer-state specs
# ---------------------------------------------------------------------------


def _table_spec(table) -> P:
    """Sketch tables are (rows, cols) with cols lane-aligned at
    construction (sketch/optimizer.py:_cols_for): shard cols the same way
    FSDP shards the largest param dim, rows replicated (rows ~ 3)."""
    c = table.shape[1]
    if c % (DATA_AXIS * MODEL_AXIS) == 0:
        return P(None, ("data", "model"))
    if c % DATA_AXIS == 0:
        return P(None, ("data",))
    return P(None, None)


def opt_state_pspecs(cfg: ModelConfig, opt_state: Any,
                     param_specs: Any) -> Any:
    """PartitionSpecs for an optimizer-state pytree.

    Dense (m, v) moments inherit their parameter's spec (the classic
    ZeRO-3 placement); CSVec sketch tables shard their column axis over
    the FSDP axes, and the (rows, 4) hash coefficients replicate.
    Works for dense AdamWState too (every moment leaf mirrors params).
    """
    from repro.sketch.csvec import CSVec
    from repro.sketch.optimizer import (DenseMoments, SketchedAdamWState,
                                        SketchedMoments)

    if not isinstance(opt_state, SketchedAdamWState):
        # dense AdamWState: step replicated, (m, v) mirror params
        return type(opt_state)(step=P(), m=param_specs, v=param_specs)

    pleaves = jax.tree.leaves(param_specs,
                              is_leaf=lambda x: isinstance(x, P))
    mleaves, mdef = jax.tree.flatten(
        opt_state.moments,
        is_leaf=lambda x: isinstance(x, (DenseMoments, SketchedMoments)))
    out = []
    for mo, pspec in zip(mleaves, pleaves):
        if isinstance(mo, SketchedMoments):
            out.append(SketchedMoments(
                m=CSVec(table=_table_spec(mo.m.table), coeffs=P(None, None),
                        d=mo.m.d, signed=mo.m.signed, seed=mo.m.seed),
                v=CSVec(table=_table_spec(mo.v.table), coeffs=P(None, None),
                        d=mo.v.d, signed=mo.v.signed, seed=mo.v.seed)))
        else:
            out.append(DenseMoments(m=pspec, v=pspec))
    return SketchedAdamWState(step=P(),
                              moments=jax.tree.unflatten(mdef, out))


# ---------------------------------------------------------------------------
# Cache specs (decode)
# ---------------------------------------------------------------------------


def cache_pspecs(cfg: ModelConfig, cache_tree: Any,
                 rules: Dict[str, MeshAxes]) -> Any:
    b = rules.get("batch")
    ks = rules.get("kv_seq")
    m = "model" if rules.get("ssm_inner") else None

    def leaf_spec(path, leaf):
        name = _last(path)
        nd = leaf.ndim
        if name in ("k", "v"):              # (L|G, B, S, K, hd)
            return P(None, b, ks, None, None)
        if name == "ssm":                   # (L, B, H, N, P)
            return P(None, b, m, None, None)
        if name in ("conv_x", "conv_BC"):   # (L, B, cw-1, C)
            return P(None, b, None, m if name == "conv_x" else None)
        if name == "C":                     # (n, B, H, hd, hd)
            return P(None, b, None, m, None)
        if name == "n":                     # (n, B, H, hd)
            return P(None, b, None, m)
        if name == "conv":                  # (n, B, 3, dm)
            return P(None, b, None, m)
        if name == "m":                     # (n, B, H)
            return P(None, b, None)
        if name in ("c", "h"):              # sLSTM states (n, B, H, hd)
            return P(None, b, None, m)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_tree)


# ---------------------------------------------------------------------------
# Continuous-batching engine state specs (decode)
# ---------------------------------------------------------------------------


def serve_state_pspecs(cfg: ModelConfig, state: Any,
                       rules: Dict[str, MeshAxes]) -> Any:
    """PartitionSpecs for a serve.scheduler.DecodeState pytree.

    Attention families carry a PAGED KV pool ((L, num_blocks, block_size,
    K, hd)): physical blocks are interchangeable, so the block axis takes
    the split-KV role the dense cache's seq axis had (rules["kv_blocks"],
    "model" on the decode mesh) and block tables replicate — every shard
    needs the full logical->physical map to gather its resident blocks.
    Recurrent families keep the stacked per-layer (X, B_slots, ...) slot
    states that cache_pspecs already places.  Per-slot bookkeeping
    (cur/pos/remaining) and per-slot sampling state (temp/top_k/keys) ride
    the batch axis so scheduler masks and the per-slot PRNG splits stay
    local to the slot's shard.  Built for the launch drivers: on a mesh,
    jit the decode chunk with these as in/out shardings (donated state
    keeps the placement stable across chunks).
    """
    from repro.serve.scheduler import DecodeState

    assert isinstance(state, DecodeState)
    b = rules.get("batch")
    slot = lambda a: P(*((b,) + (None,) * (a.ndim - 1)))
    paged = state.tables.shape[-1] > 0
    if paged:
        kb = rules.get("kv_blocks")
        pool = lambda sub: jax.tree.map(
            lambda a: P(None, kb, None, None, None), sub)
        # FCS tail tables (L, B, slot-rows Z, cols C, K, hd): the bucket
        # column axis takes the split-KV role the pool's block axis has —
        # fold scatters and tail queries then stay local per shard and
        # merge through the same head-output reduction as exact attention.
        # tail_cols() lane-aligns C to a multiple of 16, so it divides the
        # decode mesh's model axis.
        tail_sp = lambda sub: jax.tree.map(
            lambda a: P(None, b, None, kb, None, None), sub)
        cache_specs = {"kv": pool(state.cache["kv"])}
        if "tail" in state.cache:
            cache_specs["tail"] = tail_sp(state.cache["tail"])
        if "draft" in state.cache:
            # the speculative draft's shallow pool shares the target
            # pool's block geometry (same tables, same allocator), so it
            # takes the same split-KV block-axis placement
            cache_specs["draft"] = {"kv": pool(state.cache["draft"]["kv"])}
            if "tail" in state.cache["draft"]:
                cache_specs["draft"]["tail"] = tail_sp(
                    state.cache["draft"]["tail"])
        tables = P(None, None)
    else:
        cache_specs = cache_pspecs(cfg, state.cache, rules)
        tables = slot(state.tables)
    return DecodeState(
        cache=cache_specs,
        tables=tables,
        cur=slot(state.cur),
        pos=slot(state.pos),
        remaining=slot(state.remaining),
        temp=slot(state.temp),
        top_k=slot(state.top_k),
        keys=slot(state.keys),
        spec_k=slot(state.spec_k),
        fold_base=slot(state.fold_base),
    )


def draft_param_pspecs(draft, rules: Dict[str, MeshAxes]) -> Any:
    """PartitionSpecs for a speculative draft's parameter tree
    (models/draft.py:Draft): weight-stationary TP on the decode mesh,
    exactly like the served params — the draft is a plain (truncated /
    count-sketch-compressed) params tree, so the name-based TP map
    applies unchanged.  The FCS-sketched draft head (J, padded_vocab)
    shards its vocab dim over "model" with the small sketch dim
    replicated, matching the dense head's placement."""
    return build_param_pspecs(draft.cfg, draft.params, rules, "tp")
