import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on the
production mesh and record memory/cost/collective analysis.

The two lines above MUST stay first: jax locks the device count on first
init, and only the dry-run wants 512 placeholder devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out results/dryrun.json
"""
import argparse
import json
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, SHAPE_BY_NAME, shape_applicable
from repro.configs.registry import ARCH_IDS, get_config
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import build_param_pspecs, cache_pspecs, make_rules
from repro.models import model as M
from repro.models.sharding import logical_rules


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             sketch_grads: bool = False, sketched_head: bool = False,
             extra_tag: str = "", zero1: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPE_BY_NAME[shape_name]
    cell = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind, "tag": extra_tag,
    }
    if not shape_applicable(cfg, shape):
        cell["status"] = "skipped"
        cell["reason"] = ("long_500k needs sub-quadratic attention; "
                         f"{arch} is pure full-attention (see DESIGN.md)")
        return cell
    if sketch_grads or sketched_head:
        import dataclasses
        from repro.configs.base import SketchConfig
        cfg = dataclasses.replace(cfg, sketch=SketchConfig(
            sketched_head=sketched_head, grad_compression=sketch_grads))

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules, strategy = make_rules(cfg, shape.kind, shape.name == "long_500k",
                                 multi_pod, shape.global_batch)
    cell["strategy"] = strategy
    specs = M.input_specs(cfg, shape)
    pspecs = M.param_specs(cfg)
    param_sh = _named(mesh, build_param_pspecs(cfg, pspecs, rules, strategy))
    t0 = time.time()
    try:
        with mesh, logical_rules(rules):
            if shape.kind == "train":
                batch_sh = _named(mesh, jax.tree.map(
                    lambda x: P(rules["batch"], *([None] * (x.ndim - 1))),
                    specs["batch"]))
                if sketch_grads:
                    from repro.train.grad_compress import (
                        init_error_feedback, make_compressed_train_step)
                    # NOTE: grad_compress.make_podwise_compressed_step
                    # (shard_map over
                    # "pod") pins the sketch-only DCN placement but trips an
                    # XLA:CPU crash ("Invalid binary instruction opcode
                    # copy"); the global form is mathematically identical
                    # (sketch/unsketch are linear) and compiles everywhere.
                    fn = make_compressed_train_step(cfg)
                    ef_specs = jax.eval_shape(
                        lambda: init_error_feedback(
                            pspecs, cfg.sketch.grad_hash_ratio,
                            cfg.sketch.seed))
                    ef_sh = jax.tree.map(
                        lambda x: NamedSharding(mesh, P(*([None] * x.ndim))),
                        ef_specs)
                    jitted = jax.jit(fn, in_shardings=(param_sh, ef_sh,
                                                       batch_sh),
                                     out_shardings=(NamedSharding(mesh, P()),
                                                    param_sh, ef_sh))
                    lowered = jitted.lower(pspecs, ef_specs, specs["batch"])
                else:
                    fn = M.make_train_step(cfg)
                    jitted = jax.jit(fn, in_shardings=(param_sh, batch_sh),
                                     out_shardings=(NamedSharding(mesh, P()),
                                                    param_sh))
                    lowered = jitted.lower(pspecs, specs["batch"])
            elif shape.kind == "prefill":
                fn = M.make_prefill_step(cfg)
                batch_sh = _named(mesh, jax.tree.map(
                    lambda x: P(rules["batch"], *([None] * (x.ndim - 1))),
                    specs["batch"]))
                jitted = jax.jit(fn, in_shardings=(param_sh, batch_sh))
                lowered = jitted.lower(pspecs, specs["batch"])
            else:  # decode
                fn = M.make_serve_step(cfg)
                cache_sh = _named(mesh, cache_pspecs(cfg, specs["cache"], rules))
                tok_sh = NamedSharding(mesh, P(rules["batch"], None))
                idx_sh = NamedSharding(mesh, P())
                jitted = jax.jit(fn, in_shardings=(param_sh, cache_sh,
                                                   tok_sh, idx_sh),
                                 donate_argnums=(1,))
                lowered = jitted.lower(pspecs, specs["cache"],
                                       specs["tokens"], specs["index"])
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0
    except Exception as e:  # sharding bug / OOM-at-compile => system bug
        cell["status"] = "error"
        cell["error"] = f"{type(e).__name__}: {e}"
        cell["traceback"] = traceback.format_exc()[-4000:]
        return cell

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    # trip-count-weighted walk (XLA cost_analysis counts scan bodies once)
    cost = hlo_analysis.analyze(hlo)
    coll = {"ici_bytes": cost["ici_bytes"], "dcn_bytes": cost["dcn_bytes"],
            "per_op": cost["per_op"]}
    n_dev = 512 if multi_pod else 256
    flops_dev = cost["flops"]
    hbm_bytes = cost["hbm_bytes"]
    hbm_opt = cost.get("hbm_bytes_opt", hbm_bytes)
    # CPU-backend fusion is far weaker than TPU's: the instruction-level
    # bound overstates HBM traffic.  The roofline memory term uses the
    # geometric mean of [fusion-optimistic, instruction-level] bounds;
    # both endpoints are recorded.
    hbm_mid = (hbm_bytes * hbm_opt) ** 0.5 if hbm_opt > 0 else hbm_bytes
    terms = hlo_analysis.roofline_terms(flops_dev, hbm_mid, coll)

    # model FLOPs: 6*N*D train / 2*N*D fwd over ACTIVE params
    n_params = sum(x.size for x in jax.tree.leaves(pspecs))
    n_active = n_params
    if cfg.moe:
        m = cfg.moe
        per_expert = 3 * cfg.d_model * m.expert_d_ff
        n_active -= (m.num_experts - m.top_k) * per_expert * cfg.num_layers
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind in ("train", "prefill") else shape.global_batch)
    mult = 6.0 if shape.kind == "train" else 2.0
    model_flops = mult * n_active * tokens
    useful_ratio = model_flops / (flops_dev * n_dev) if flops_dev else 0.0

    cell.update({
        "status": "ok",
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
        "devices": n_dev,
        "memory": {
            "argument_bytes_per_device": ma.argument_size_in_bytes,
            "output_bytes_per_device": ma.output_size_in_bytes,
            "temp_bytes_per_device": ma.temp_size_in_bytes,
            "alias_bytes_per_device": ma.alias_size_in_bytes,
            "peak_bytes_per_device": (ma.argument_size_in_bytes
                                      + ma.output_size_in_bytes
                                      + ma.temp_size_in_bytes
                                      - ma.alias_size_in_bytes),
        },
        "cost": {"flops_per_device": flops_dev,
                 "hbm_bytes_per_device": hbm_mid,
                 "hbm_bytes_pessimistic": hbm_bytes,
                 "hbm_bytes_optimistic": hbm_opt,
                 "xla_flops_unweighted": float(ca.get("flops", 0.0)),
                 "xla_bytes_unweighted": float(ca.get("bytes accessed", 0.0))},
        "collectives": {k: v for k, v in coll.items() if k != "per_op"},
        "collective_ops": coll["per_op"],
        "roofline": terms,
        "params_total": int(n_params),
        "params_active": int(n_active),
        "model_flops": model_flops,
        "useful_flops_ratio": useful_ratio,
    })
    return cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=[s.name for s in SHAPES])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--sketch-grads", action="store_true",
                    help="FCS gradient compression on the pod axis")
    ap.add_argument("--sketched-head", action="store_true",
                    help="FCS-sketched LM head")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = ARCH_IDS if args.all or not args.arch else [args.arch]
    shapes = ([s.name for s in SHAPES] if args.all or not args.shape
              else [args.shape])
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"], r.get("tag", ""))
            for r in results if r.get("status") in ("ok", "skipped")}

    for multi_pod in meshes:
        mesh_name = "2x16x16" if multi_pod else "16x16"
        for arch in archs:
            for shape in shapes:
                key = (arch, shape, mesh_name, args.tag)
                if key in done:
                    continue
                print(f"=== {arch} x {shape} x {mesh_name}"
                      f"{' [' + args.tag + ']' if args.tag else ''} ===",
                      flush=True)
                cell = run_cell(arch, shape, multi_pod,
                                sketch_grads=args.sketch_grads,
                                sketched_head=args.sketched_head,
                                extra_tag=args.tag, zero1=args.zero1)
                print(json.dumps({k: cell.get(k) for k in
                                  ("status", "t_compile_s", "error")},
                                 indent=None), flush=True)
                if cell["status"] == "ok":
                    mem = cell["memory"]["peak_bytes_per_device"] / 2**30
                    rf = cell["roofline"]
                    print(f"  peak {mem:.2f} GiB/dev | compute {rf['t_compute_s']*1e3:.2f} ms"
                          f" | memory {rf['t_memory_s']*1e3:.2f} ms"
                          f" | coll {rf['t_collective_s']*1e3:.2f} ms"
                          f" | dominant={rf['dominant']}", flush=True)
                results = [r for r in results
                           if (r["arch"], r["shape"], r["mesh"],
                               r.get("tag", "")) != key]
                results.append(cell)
                os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                tmp = args.out + ".tmp"
                with open(tmp, "w") as f:
                    json.dump(results, f, indent=1)
                os.replace(tmp, args.out)
    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_skip = sum(1 for r in results if r["status"] == "skipped")
    n_err = sum(1 for r in results if r["status"] == "error")
    print(f"DONE: {n_ok} ok, {n_skip} skipped, {n_err} errors -> {args.out}")


if __name__ == "__main__":
    main()
