"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400, MoE 64e top-6, 2 shared experts (fine-grained).
[arXiv:2401.06066; hf]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102_400,
    act="silu",
    moe=MoEConfig(num_experts=64, top_k=6, num_shared_experts=2,
                  expert_d_ff=1408),
    source="arXiv:2401.06066",
)
