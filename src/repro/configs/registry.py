"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict

from repro.configs.base import ModelConfig

_ARCH_MODULES = {
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "musicgen-medium": "repro.configs.musicgen_medium",
    "yi-9b": "repro.configs.yi_9b",
    "gemma-2b": "repro.configs.gemma_2b",
    "minitron-4b": "repro.configs.minitron_4b",
    "qwen2.5-32b": "repro.configs.qwen2_5_32b",
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
    "internvl2-2b": "repro.configs.internvl2_2b",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests: same family/topology, tiny dims.
# ---------------------------------------------------------------------------


def reduced_config(arch: str) -> ModelConfig:
    cfg = get_config(arch)
    changes: dict = dict(
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=512,
    )
    if cfg.family == "moe":
        changes["num_layers"] = 2
        changes["moe"] = dataclasses.replace(
            cfg.moe, num_experts=8, top_k=2, expert_d_ff=64, group_size=32,
            num_shared_experts=min(cfg.moe.num_shared_experts, 1))
    elif cfg.family == "ssm":
        changes["num_layers"] = cfg.xlstm.m_per_group + cfg.xlstm.s_per_group
        changes["num_heads"] = 2
        changes["num_kv_heads"] = 2
    elif cfg.family == "hybrid":
        changes["num_layers"] = 2 * cfg.hybrid.mamba_per_group
        changes["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=16,
                                             chunk=16)
    else:
        changes["num_layers"] = 2
    return dataclasses.replace(cfg, **changes)
