"""xlstm-1.3b [ssm] — 48L d_model=2048 4H d_ff=0 vocab=50304;
sLSTM + mLSTM blocks (xLSTM[7:1] pattern).  [arXiv:2405.04517; unverified]

d_ff=0: xLSTM blocks carry their own up/down projections (proj factor 2 for
mLSTM, 4/3 for sLSTM) instead of a separate FFN.
Attention-free: long_500k decode RUNS (constant-size recurrent state).
"""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    act="gelu",
    xlstm=XLSTMConfig(m_per_group=7, s_per_group=1),
    supports_long_context=True,
    source="arXiv:2405.04517",
)
