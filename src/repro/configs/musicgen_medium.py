"""musicgen-medium [audio] — 48L d_model=1536 24H (GQA kv=24) d_ff=6144
vocab=2048; decoder-only over EnCodec tokens.  [arXiv:2306.05284; hf]

The EnCodec frontend is a STUB: ``input_specs()`` provides precomputed frame
embeddings for train/prefill; decode runs over the 2048-entry codebook vocab.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    act="gelu",
    frontend="audio_stub",
    source="arXiv:2306.05284",
)
