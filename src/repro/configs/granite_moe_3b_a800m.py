"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40e top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

The assignment header says "MoE 40e top-8" while the trailing note says
"32 experts top-8"; we follow the structured field (40 experts).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49_155,
    act="silu",
    moe=MoEConfig(num_experts=40, top_k=8, num_shared_experts=0,
                  expert_d_ff=512),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
