"""Config system for sketchtrax.

Every assigned architecture gets one module in this package exporting
``CONFIG: ModelConfig``.  ``repro.configs.registry`` maps ``--arch`` ids to
them.  Configs are plain frozen dataclasses so they can be hashed into jit
static args and serialized into checkpoints.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sketch (paper technique) configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SketchConfig:
    """Fast-Count-Sketch settings for framework integration points.

    ``head_mode_hash_len``: per-mode hash length J_n used when sketching the
    LM head weight (treated as an order-2 tensor (d_model, vocab)).  The
    sketched dim is J~ = sum(J_n) - N + 1.
    ``grad_hash_ratio``: target compression ratio for FCS gradient
    compression on the pod axis (sketch length ~= numel / ratio).
    ``num_sketches``: D independent sketches (median combine).
    ``opt_state_ratio``: > 0 moves AdamW (m, v) moments for large leaves
    into count-sketch tables of ~numel/ratio entries per moment
    (repro.sketch.optimizer); 0 keeps the dense optimizer (default).
    ``opt_state_rows``: sketch rows per table (median/min combine width).
    ``opt_state_min_elems``: leaves smaller than this stay dense.
    """

    sketched_head: bool = False
    head_hash_len: int = 4096
    grad_compression: bool = False
    grad_hash_ratio: int = 16
    num_sketches: int = 1
    opt_state_ratio: int = 0
    opt_state_rows: int = 3
    opt_state_min_elems: int = 1 << 16
    seed: int = 1234


# ---------------------------------------------------------------------------
# Serving configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Continuous-batching engine knobs (repro.serve.scheduler).

    ``max_batch``/``max_seq``: the fixed slot-state geometry — attention
    families preallocate a (L, max_batch, max_seq, K, hd) KV cache,
    recurrent families their stacked per-layer states, and the decode step
    compiles exactly once for the engine's lifetime.  Sampling params
    (temperature / top-k / seed) are per-request, carried as per-slot
    engine state — they don't specialize the compiled chunk.
    ``decode_chunk``: decode steps per scheduler intervention (the jitted
    lax.scan length); admission/retirement happens between chunks.
    ``prefill_bucket``: the chunked-prefill chunk size for attention
    families — prompts (and cached-prefix suffixes of any length) are fed
    through one offset-traced compiled chunk of this many tokens, so
    prefill compiles once regardless of prompt lengths.  The tail chunk is
    zero-padded; pad rows are causally dead, but for the moe family they
    still compete in expert-capacity dispatch — set 1 for exact-length
    chunks.  Recurrent families ignore it (exact-length prefill: trailing
    pad tokens would corrupt a recurrence).
    ``kv_block_size``: paged-KV page size in tokens.  Attention families
    store KV in a (L, num_kv_blocks, kv_block_size, K, hd) block pool;
    each slot holds a block table of physical block ids, so a request
    reserves ceil((S + max_new) / kv_block_size) blocks instead of
    max_seq rows.  Must divide ``prefix_block`` so cached prefixes share
    whole pool blocks by reference.
    ``num_kv_blocks``: pool size in blocks; 0 (default) auto-sizes to the
    dense equivalent, max_batch * ceil(max_seq / kv_block_size) — set it
    lower to cap pool memory (admission then waits for free blocks and
    evicts idle prefix-cache entries under pressure).
    ``admit_threshold``: a prompt prefix's KV block is admitted to the
    bounded prefix cache only once its count-min estimated frequency
    reaches this value (TinyLFU-style sketch-gated admission; count-min's
    one-sided overestimate can only admit early, never starve).
    ``prefix_block``: prefix granularity in tokens — block-multiple
    prefixes are counted/cached.
    ``prefix_cache_bytes``: hard byte budget for prefix-cache-held pool
    blocks (LRU eviction keeps the total at or under this; an entry's
    blocks only return to the free list when no live slot references
    them).
    ``cm_cols``/``cm_rows``: count-min table geometry (O(table) state
    regardless of unique-prompt cardinality).
    ``cm_decay_every``/``cm_decay``: every N observed prompts the counts
    are aged by the decay factor so stale prefixes lose admission priority.
    ``spec_k``: speculative decoding (attention families): a cheap draft
    model proposes up to ``spec_k`` tokens per slot per round and the
    served model verifies all of them in ONE multi-query decode step
    (``transformer.verify_step``); greedy speculative output is
    token-for-token identical to plain greedy decode.  0 (default)
    disables speculation and keeps the classic one-token decode chunk.
    Per-request ``Request.spec_k`` overrides, clamped to this engine max.
    ``draft_depth``: layers of the served stack kept in the derived draft
    proposer (``models/draft.py:make_draft`` — a truncated prefix of the
    block stack sharing embed/norm/head).
    ``draft_sketch_ratio``: > 0 additionally count-sketch-compresses the
    draft's block weights along their contraction dim at this ratio and
    swaps the draft's LM head for the FCS-sketched head (paper Section
    4.2 machinery) at the same ratio — the paper's compressed-forward
    recipe applied to drafting.  0 keeps the truncated weights dense.
    ``kv_sketch_window``: > 0 turns on the sketched long-context KV
    subsystem (attention families, ``serve/kv_sketch.py``): each slot
    keeps this many recent tokens of EXACT paged KV; when a whole block
    ages past the window it is folded into a per-slot, per-layer
    count-sketch tail table (keys and values sketched along the sequence
    axis with ``sketch/hashing.py`` rows) and freed back to the pool, so
    a slot's pool reservation is bounded by the window, not the context.
    Decode attention becomes two-span: exact over the window plus an
    approximate tail contribution merged with online-softmax statistics.
    Must be a multiple of ``kv_block_size``.  0 (default) disables the
    subsystem entirely — the engine builds the classic exact graph.
    Per-request ``Request.kv_sketch=False`` opts a request out (it then
    reserves its full context exactly, as without the subsystem).
    ``kv_sketch_ratio``: sequence-axis compression ratio of the tail
    tables — each table row has ~max_seq/ratio columns (lane-aligned), so
    tail bytes are ~2 * rows/ratio of the folded KV bytes.
    ``kv_sketch_rows``: independent hash rows per tail table (median
    combine width; the FCS D parameter applied to KV).
    ``queue_depth``: backpressure bound for the async front-end
    (``serve/frontend.py``): at most this many submitted-but-unadmitted
    requests may wait in the scheduler queue; an ``AsyncServeEngine.
    submit`` beyond it awaits (never raises) until admissions/retirements
    drain the queue.  The synchronous ``SlotScheduler.submit`` path is
    not bounded — batch callers hand the whole request list over at once.
    ``default_deadline_s``: seconds-from-submission deadline applied to
    requests that don't carry their own; a request past its deadline is
    expired — dropped from the queue, or retired mid-flight with the
    tokens it produced so far (``Completion.status == "expired"``).
    0 (default) means no deadline.
    ``preemption``: allow the admission path to preempt a strictly
    lower-priority running slot when a higher-priority request cannot be
    admitted (no free slot, or the block pool can't serve it).  The
    victim retires through the normal slot-retire + block-free path and
    is requeued as a continuation request (prompt + tokens so far), so
    its final output is unchanged — preemption trades its latency for
    the high-priority request's.  True by default; deadline expiry works
    regardless.
    ``paged_kernels``: attention implementation for the paged serve path
    (decode / speculative verify / chunked prefill).  None (default)
    auto-detects: the flash-decode Pallas kernels
    (``kernels/paged_attention.py`` — one pass over each slot's block
    table, no dense gathered KV copy) on TPU, the jnp
    gather-then-softmax oracle path elsewhere.  True forces the kernels
    (interpret mode off-TPU — the validation configuration), False
    forces the jnp path.  Resolved once at engine construction; both
    choices keep the one-compilation-per-engine contract and the
    sketched two-span fold_base == 0 bitwise anchor.
    """

    max_batch: int = 8
    max_seq: int = 512
    decode_chunk: int = 8
    prefill_bucket: int = 32
    kv_block_size: int = 16
    num_kv_blocks: int = 0
    admit_threshold: int = 2
    prefix_block: int = 16
    prefix_cache_bytes: int = 1 << 24
    cm_cols: int = 1024
    cm_rows: int = 4
    cm_decay_every: int = 1024
    cm_decay: float = 0.5
    seed: int = 0
    spec_k: int = 0
    draft_depth: int = 1
    draft_sketch_ratio: int = 0
    kv_sketch_window: int = 0
    kv_sketch_ratio: int = 8
    kv_sketch_rows: int = 3
    queue_depth: int = 64
    default_deadline_s: float = 0.0
    preemption: bool = True
    paged_kernels: Optional[bool] = None


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    expert_d_ff: int = 0
    group_size: int = 128          # GShard dispatch group (tokens)
    capacity_factor: float = 1.5
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    # xLSTM[m:s] block pattern: each group = `m_per_group` mLSTM blocks
    # followed by `s_per_group` sLSTM blocks.
    m_per_group: int = 7
    s_per_group: int = 1
    proj_factor_m: float = 2.0
    proj_factor_s: float = 4.0 / 3.0


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    # Zamba2-style: `mamba_per_group` Mamba2 layers then one application of a
    # shared transformer block; `num_shared_blocks` distinct shared blocks
    # used round-robin.
    mamba_per_group: int = 6
    num_shared_blocks: int = 2


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    act: str = "silu"                # silu | gelu
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # sub-config blocks (None for families that don't use them)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    hybrid: Optional[HybridConfig] = None
    sketch: SketchConfig = SketchConfig()
    serve: ServeConfig = ServeConfig()
    # frontend stub for [audio]/[vlm]: train/prefill consume precomputed
    # frame/patch embeddings instead of token ids.
    frontend: str = "none"           # none | audio_stub | vision_stub
    # True when decode with a 500k context is architecturally sane
    # (sub-quadratic / constant-state sequence mixing).
    supports_long_context: bool = False
    # source citation for the config
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 (Megatron-style) so the head is
        evenly shardable over a 16-way model axis with 128-lane alignment."""
        return int(math.ceil(self.vocab_size / 256) * 256)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Exact parameter count of the model as implemented (total)."""
        d, v = self.d_model, self.padded_vocab
        n = 0
        n += v * d                                # embedding
        if not self.tie_embeddings:
            n += v * d                            # head
        n += d                                    # final norm
        per_layer = self._block_param_count()
        n += per_layer
        return n

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed-in experts)."""
        if self.family != "moe" or self.moe is None:
            return self.param_count()
        d = self.d_model
        m = self.moe
        total = self.param_count()
        # subtract inactive routed experts
        expert_params = 3 * d * m.expert_d_ff
        inactive = (m.num_experts - m.top_k) * expert_params * self.num_layers
        return total - inactive

    def _block_param_count(self) -> int:
        d = self.d_model
        hd = self.resolved_head_dim
        H, K = self.num_heads, self.num_kv_heads
        attn = d * H * hd + 2 * d * K * hd + H * hd * d
        if self.qkv_bias:
            attn += (H + 2 * K) * hd
        ffn_glu = 3 * d * self.d_ff
        norms = 2 * d
        L = self.num_layers
        if self.family in ("dense", "audio", "vlm"):
            return L * (attn + ffn_glu + norms)
        if self.family == "moe":
            m = self.moe
            router = d * m.num_experts
            experts = m.num_experts * 3 * d * m.expert_d_ff
            shared = m.num_shared_experts * 3 * d * m.expert_d_ff
            return L * (attn + router + experts + shared + norms)
        if self.family == "ssm":
            x = self.xlstm
            gm = x.m_per_group + x.s_per_group
            n_groups = L // gm
            dm = int(d * x.proj_factor_m)
            # mLSTM block: up-proj (2x for gate), qkv projections on inner dim,
            # i/f/o gate projections, down-proj, norms
            mlstm = (2 * d * dm) + 3 * dm * (dm // self.num_heads) * self.num_heads \
                + 3 * dm * self.num_heads + dm * d + 2 * d + 2 * dm
            ds = int(d * x.proj_factor_s)
            # sLSTM: 4 gates x (input proj + recurrent per-head proj) + FFN-ish up/down
            slstm = 4 * (d * d + self.num_heads * (d // self.num_heads) ** 2) \
                + 2 * d * ds + ds * d + 2 * d
            return n_groups * (x.m_per_group * mlstm + x.s_per_group * slstm)
        if self.family == "hybrid":
            hb = self.hybrid
            s = self.ssm
            di = s.expand * d
            nheads = di // s.head_dim
            # mamba2 block params
            mamba = d * (2 * di + 2 * s.d_state + nheads) + s.conv_width * (di + 2 * s.d_state) \
                + nheads + nheads + di * d + d + di
            shared_blk = (d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
                          + self.num_heads * hd * d + 3 * d * self.d_ff + 2 * d)
            return L * mamba + hb.num_shared_blocks * shared_blk
        raise ValueError(self.family)

    def flops_per_token(self, seq_len: int, training: bool) -> float:
        """Model FLOPs per token: 6*N_active (train) or 2*N_active (fwd),
        plus attention score FLOPs where applicable."""
        n = self.active_param_count()
        base = (6.0 if training else 2.0) * n
        # causal attention term: 2 * 2 * hd * H * S/2 per token per layer
        hd = self.resolved_head_dim
        if self.family in ("dense", "audio", "vlm", "moe"):
            attn_layers = self.num_layers
        elif self.family == "hybrid":
            attn_layers = self.num_layers // self.hybrid.mamba_per_group
        else:
            attn_layers = 0
        attn = attn_layers * 2 * 2 * self.num_heads * hd * (seq_len / 2)
        if training:
            attn *= 3
        return base + attn


# ---------------------------------------------------------------------------
# Input shapes (assigned shape set for LM-family transformers)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k only runs for sub-quadratic sequence mixers (SSM/hybrid)."""
    if shape.name == "long_500k":
        return cfg.supports_long_context
    return True
