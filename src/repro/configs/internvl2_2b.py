"""internvl2-2b [vlm] — 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553; InternViT + InternLM2.  [arXiv:2404.16821; hf]

The InternViT frontend is a STUB: ``input_specs()`` provides precomputed
patch embeddings; this config describes the InternLM2 language backbone.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92_553,
    act="silu",
    frontend="vision_stub",
    source="arXiv:2404.16821",
)
