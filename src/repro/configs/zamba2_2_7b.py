"""zamba2-2.7b [hybrid] — 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64; Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; hf]

Structure: 54 Mamba2 layers; after every 6 Mamba2 layers one of 2 shared
full transformer blocks (attention+FFN) is applied round-robin (real Zamba2
adds per-application LoRA deltas to the shared block — omitted, noted in
DESIGN.md).  Hybrid: long_500k decode RUNS (Mamba2 state is constant-size;
the shared attention KV at 500k is sharded over the mesh).
"""
from repro.configs.base import ModelConfig, SSMConfig, HybridConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32_000,
    act="gelu",
    ssm=SSMConfig(d_state=64, expand=2, head_dim=64, chunk=64),
    hybrid=HybridConfig(mamba_per_group=6, num_shared_blocks=2),
    supports_long_context=True,
    source="arXiv:2411.15242",
)
