"""Draft-model derivation for speculative decoding.

The serve engine's speculative path needs a proposer that is much cheaper
than the served model but agrees with it often enough that verified
acceptance runs are long.  Two derivations, composable:

  * truncation (``draft_depth``): the draft keeps the FIRST ``depth``
    layers of the served block stack (the per-layer leaves are stacked on
    a leading L axis, so truncation is one slice per leaf) and shares the
    embedding, final norm and LM head.  Early layers carry most of the
    next-token signal, so a shallow prefix is the classic cheap draft.

  * count-sketch compression (``draft_sketch_ratio`` > 0): every block
    matmul weight is replaced by its count-sketch reconstruction along
    the CONTRACTION dim — W ~= median_r S_r^T S_r W with the O(1)-storage
    hash family from ``sketch/hashing.py`` — and the LM head is swapped
    for the FCS-sketched head of ``models/layers.py`` (paper Section 4.2:
    activations are count-sketched per token, the projection lives in the
    J-dim sketch space).  This is the paper's compressed-forward recipe
    (HCS / tensor-regression compression, arXiv:1901.11261) applied to
    drafting: the sketch preserves enough of the operator that the
    compressed forward pass is a usable approximation, not just an
    estimator.

Either way the draft is a plain params tree + ModelConfig that runs
through the unchanged ``transformer`` decode/prefill paths — the
scheduler treats it as just another attention-family model with its own
(shallow) paged KV pool riding the same block tables as the target.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ServeConfig
from repro.models import layers as ly
from repro.sketch import hashing

ATTENTION_FAMILIES = ("dense", "moe", "audio", "vlm")


class Draft(NamedTuple):
    """A derived proposer: params + the config that interprets them."""
    params: Any
    cfg: ModelConfig


# ---------------------------------------------------------------------------
# Truncation
# ---------------------------------------------------------------------------


def truncate_params(params: Any, cfg: ModelConfig, depth: int):
    """Shallow draft: the first ``depth`` layers of the block stack with
    shared embed / final norm / head.  Returns (draft_params, draft_cfg).
    Attention families only — recurrent stacks interleave block types in
    grouped patterns that a leading-axis slice would scramble."""
    if cfg.family not in ATTENTION_FAMILIES:
        raise ValueError(f"draft truncation needs an attention family, "
                         f"got {cfg.family!r}")
    depth = int(depth)
    if not 1 <= depth <= cfg.num_layers:
        raise ValueError(f"draft_depth {depth} outside [1, {cfg.num_layers}]")
    blocks = jax.tree.map(lambda a: a[:depth], params["blocks"])
    dcfg = dataclasses.replace(cfg, num_layers=depth)
    return {**params, "blocks": blocks}, dcfg


# ---------------------------------------------------------------------------
# Count-sketch weight compression
# ---------------------------------------------------------------------------


def _cs_reconstruct(w: jax.Array, ratio: int, rows: int,
                    seed: int) -> jax.Array:
    """Count-sketch a (d_in, d_out) matrix along d_in (the contraction
    dim) into J = d_in // ratio buckets and reconstruct: the median over
    ``rows`` independent hash rows of S_r^T (S_r W).  Unbiased per
    element; collisions inject zero-mean noise that shrinks with J."""
    d_in = w.shape[0]
    J = max(1, d_in // max(1, ratio))
    if J >= d_in:
        return w
    coeffs = hashing.cached_coeffs(seed, rows)
    idx = jnp.arange(d_in, dtype=jnp.int32)
    bk, sg = hashing.row_buckets_signs(coeffs, idx, J, signed=True)
    wf = w.astype(jnp.float32)
    est = []
    for r in range(rows):
        table = jnp.zeros((J, wf.shape[1]), jnp.float32
                          ).at[bk[r]].add(sg[r][:, None] * wf)
        est.append(sg[r][:, None] * table[bk[r]])
    return jnp.median(jnp.stack(est), axis=0).astype(w.dtype)


def _compress_leaf(path, w: jax.Array, ratio: int, rows: int,
                   base_seed: int) -> jax.Array:
    """Compress one stacked block leaf (..., d_in, d_out) along its
    contraction (second-to-last) axis; 1D leaves (norms, biases, per-head
    scalars) pass through untouched."""
    if w.ndim < 3:          # (L, d) norms / (L, h) biases: nothing to sketch
        return w
    shp = w.shape
    lead = int(np.prod(shp[:-2]))
    wf = w.reshape(lead, shp[-2], shp[-1])
    # a distinct, process-salt-free hash seed per (leaf, slice):
    # correlated collision patterns across layers would bias every layer
    # the same way, and the derivation must be reproducible across runs
    name = "/".join(str(getattr(k, "key", k)) for k in path)
    leaf_seed = (base_seed * 1_000_003
                 + zlib.crc32(name.encode())) & 0x7FFFFFFF
    out = [_cs_reconstruct(wf[i], ratio, rows, leaf_seed + i)
           for i in range(lead)]
    return jnp.stack(out).reshape(shp)


def sketch_head(params: Any, cfg: ModelConfig, J: int,
                seed: int) -> jax.Array:
    """Derive the (J, padded_vocab) FCS-sketched head from the dense head
    (or the tied embedding): head_sk = (one_hot(h) * sg)^T W, the exact
    counterpart of the activation sketch ``layers._head_io`` applies, so
    logits ~= x W with CR = d_model / J."""
    W = (params["head"] if params.get("head") is not None
         else params["embed"].T)
    h, sg = ly._head_hash_tables(seed, cfg.d_model, J)
    onehot = (jax.nn.one_hot(jnp.asarray(h), J, dtype=jnp.float32)
              * jnp.asarray(sg)[:, None])                 # (d, J)
    return jnp.einsum("dj,dv->jv", onehot,
                      W.astype(jnp.float32)).astype(ly.PDTYPE)


def compress_params(params: Any, cfg: ModelConfig, depth: int,
                    ratio: int, rows: int = 3,
                    seed: Optional[int] = None):
    """FCS/count-sketch-compressed draft: truncate to ``depth`` layers,
    reconstruct every block matmul weight through a ratio-J count sketch,
    and replace the LM head with the sketched head at the same ratio.
    Returns (draft_params, draft_cfg); ``ratio <= 1`` degenerates to pure
    truncation (dense weights, dense head)."""
    dparams, dcfg = truncate_params(params, cfg, depth)
    if ratio <= 1:
        return dparams, dcfg
    seed = cfg.sketch.seed if seed is None else seed
    dparams = dict(dparams)
    dparams["blocks"] = jax.tree_util.tree_map_with_path(
        lambda p, w: _compress_leaf(p, w, ratio, rows, seed),
        dparams["blocks"])
    J = max(1, cfg.d_model // ratio)
    dparams["head"] = sketch_head(params, cfg, J, seed)
    dcfg = dataclasses.replace(
        dcfg, tie_embeddings=False,
        sketch=dataclasses.replace(cfg.sketch, sketched_head=True,
                                   head_hash_len=J, seed=seed))
    return dparams, dcfg


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


def make_draft(params: Any, cfg: ModelConfig,
               serve: Optional[ServeConfig] = None) -> Optional[Draft]:
    """Build the draft the serve config asks for: None when speculation
    is off (``spec_k == 0``) or the family has no KV cache to verify
    against; otherwise a ``draft_depth``-layer truncation, additionally
    count-sketch-compressed when ``draft_sketch_ratio > 0``."""
    sv = serve if serve is not None else cfg.serve
    if sv.spec_k <= 0 or cfg.family not in ATTENTION_FAMILIES:
        return None
    depth = min(max(1, sv.draft_depth), cfg.num_layers)
    dparams, dcfg = compress_params(params, cfg, depth,
                                    sv.draft_sketch_ratio)
    return Draft(params=dparams, cfg=dcfg)
