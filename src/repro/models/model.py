"""Public model API + dry-run input specs.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
input of the step function selected by the shape kind (train_step /
prefill_step / serve_step), weak-type-correct and shardable, with no device
allocation.  [audio]/[vlm] train/prefill inputs are precomputed frontend
embeddings (the modality frontend is a stub per the assignment).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tf
from repro.models.transformer import (  # re-exports (public API)
    init_params, forward, loss_fn, decode_step, prefill, init_cache,
)

__all__ = [
    "init_params", "forward", "loss_fn", "decode_step", "prefill",
    "init_cache", "input_specs", "param_specs", "cache_specs",
]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def param_specs(cfg: ModelConfig) -> Any:
    """Shape/dtype tree of the parameters (no allocation)."""
    return jax.eval_shape(lambda k: tf.init_params(k, cfg),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int) -> Any:
    return jax.eval_shape(
        lambda: tf.init_cache(cfg, batch, max_seq))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Inputs for the step function implied by ``shape.kind``.

    train   -> {"batch": {tokens|embeds, labels}}
    prefill -> {"batch": {tokens|embeds}}
    decode  -> {"cache": <tree>, "tokens": (B,1), "index": ()}
    """
    B, S = shape.global_batch, shape.seq_len
    stub = cfg.frontend != "none"
    if shape.kind == "train":
        if stub:
            batch = {"embeds": _sds((B, S, cfg.d_model), jnp.bfloat16),
                     "labels": _sds((B, S), jnp.int32)}
        else:
            batch = {"tokens": _sds((B, S), jnp.int32),
                     "labels": _sds((B, S), jnp.int32)}
        return {"batch": batch}
    if shape.kind == "prefill":
        if stub:
            return {"batch": {"embeds": _sds((B, S, cfg.d_model), jnp.bfloat16)}}
        return {"batch": {"tokens": _sds((B, S), jnp.int32)}}
    if shape.kind == "decode":
        cache = jax.tree.map(
            lambda x: _sds(x.shape, x.dtype), cache_specs(cfg, B, S))
        return {
            "cache": cache,
            "tokens": _sds((B, 1), jnp.int32),
            "index": _sds((), jnp.int32),
        }
    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# Step functions lowered by the dry-run / drivers
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig):
    """Gradient-only step (optimizer handled by repro.train); returns
    (loss, grads) — the canonical object the dry-run lowers for `train`."""
    def train_step(params, batch):
        loss, grads = jax.value_and_grad(tf.loss_fn)(params, batch, cfg)
        return loss, grads
    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return tf.prefill(params, batch, cfg)
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, tokens, index):
        return tf.decode_step(params, cache, tokens, index, cfg)
    return serve_step
