"""Logical-axis sharding (flax-style logical rules, dependency-free).

Model code annotates activations with *logical* axis names via ``shard(x,
"batch", "seq", "heads", None)``.  A rules dict (logical name -> mesh axis or
tuple of mesh axes or None) is installed with ``logical_rules`` around trace
time; outside of any rules context ``shard`` is a no-op, so the same model
code runs un-sharded in CPU smoke tests.

Uneven dims (e.g. 40 heads over a 16-way "model" axis) are allowed on
activation constraints — GSPMD pads internally (verified on jax 0.8).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

_CTX = threading.local()


def current_rules() -> Optional[Dict[str, MeshAxes]]:
    return getattr(_CTX, "rules", None)


@contextlib.contextmanager
def logical_rules(rules: Optional[Dict[str, MeshAxes]]):
    old = current_rules()
    _CTX.rules = rules
    try:
        yield
    finally:
        _CTX.rules = old


def spec_for(axes: Sequence[Optional[str]],
             rules: Optional[Dict[str, MeshAxes]] = None) -> P:
    rules = rules if rules is not None else (current_rules() or {})
    return P(*(rules.get(a) if a is not None else None for a in axes))


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Constrain ``x`` so logical axis i is sharded per the active rules."""
    rules = current_rules()
    if not rules:
        return x
    assert len(axes) == x.ndim, (axes, x.shape)
    return jax.lax.with_sharding_constraint(x, spec_for(axes, rules))


# ---------------------------------------------------------------------------
# Canonical rule sets for the production mesh.
# ---------------------------------------------------------------------------


def train_rules(multi_pod: bool, strategy: str = "fsdp_seq",
                expert_parallel: bool = False) -> Dict[str, MeshAxes]:
    """Activation rules for train/prefill.

    fsdp_seq   : batch over (pod,)data + context sharding of seq over model;
                 params FSDP-sharded (see launch.shardings).  Attention runs
                 flash-style with replicated KV (cheap AG for GQA); SSD uses
                 an associative scan so the chunk recurrence parallelizes
                 across seq shards.  The default for attention + hybrid archs.
    fsdp_batch : batch over (data, model) — one sequence per device; params
                 FSDP-sharded; everything token-local (xLSTM single-pod).
    tp         : batch over (pod,)data + inner-dim tensor parallelism over
                 model (xLSTM multi-pod / prefill: mLSTM KV is full-width, so
                 context sharding would all-gather dm-sized tensors).
    """
    dp = ("pod", "data") if multi_pod else ("data",)
    base = {
        "batch": dp, "seq": None, "residual": None, "kv_seq": None,
        "kv_blocks": None,
        "heads": None, "kv_heads": None, "embed": None, "ff": None,
        "vocab": None, "experts": "model" if expert_parallel else None,
        "expert_cap": None, "ssm_inner": None, "ssm_heads": None,
        "state": None, "zero": "data",
        # MoE dispatch groups: over every data-parallel axis; additionally
        # over "model" under FSDP strategies without expert parallelism
        # (when "model" carries neither experts nor the experts' d_ff).
        "moe_groups": dp,
    }
    base["chunks"] = None
    base["ctx_shards"] = 1
    if strategy.startswith("fsdp") and not expert_parallel:
        base["moe_groups"] = dp + ("model",)
    if strategy == "fsdp_seq":
        base.update({"seq": "model", "residual": "model", "chunks": "model",
                     "ctx_shards": 16})
    elif strategy == "fsdp_batch":
        base.update({"batch": ("data", "model")})
    elif strategy == "tp":
        base.update({"ssm_inner": "model", "ssm_heads": "model",
                     "ff": "model", "vocab": "model",
                     "heads": "model"})
    else:
        raise ValueError(strategy)
    return base


def decode_rules(multi_pod: bool, long_context: bool) -> Dict[str, MeshAxes]:
    # Decode: weight-stationary TP over model (params stay sharded; no
    # per-token gathers) + the KV cache sharded along its *sequence* dim
    # (split-KV, FlashDecoding-style): softmax max/sum stats over the sharded
    # axis are combined by the SPMD partitioner's cross-shard reductions.
    # Heads stay unsharded for the 1-token query (kv-head counts (1..32)
    # don't divide the 16-way model axis for several archs; seq always does).
    r = train_rules(multi_pod, strategy="tp")
    r["heads"] = None
    r["residual"] = None  # decode S=1
    if long_context:
        # batch==1: shard the KV/sequence dim over data AND model.
        r["batch"] = None
        r["kv_seq"] = ("data", "model")
        r["kv_blocks"] = ("data", "model")
    else:
        r["kv_seq"] = "model"
        # paged pool: physical blocks are interchangeable, so the block
        # axis takes the split-KV role the dense cache's seq axis had
        r["kv_blocks"] = "model"
    return r
