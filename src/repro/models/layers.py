"""Shared layers: norms, RoPE, GQA/MQA attention (chunked causal + decode),
GLU FFN, embeddings, (optionally FCS-sketched) LM head.

All layers are pure functions over explicit param pytrees; init_* builders
mirror the apply functions.  Weights are bf16; softmax / norms / losses
accumulate in f32.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.models.sharding import shard

PDTYPE = jnp.bfloat16  # parameter dtype


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def init_rms_norm(d: int) -> jax.Array:
    return jnp.zeros((d,), PDTYPE)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, n, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos, sin = cos[..., None, :], sin[..., None, :]  # add head axis
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA / MQA)
# ---------------------------------------------------------------------------


def init_attention(key: jax.Array, cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    sd = 1.0 / math.sqrt(d)
    p = {
        "wq": (jax.random.normal(k1, (d, H * hd)) * sd).astype(PDTYPE),
        "wk": (jax.random.normal(k2, (d, K * hd)) * sd).astype(PDTYPE),
        "wv": (jax.random.normal(k3, (d, K * hd)) * sd).astype(PDTYPE),
        "wo": (jax.random.normal(k4, (H * hd, d)) / math.sqrt(H * hd)).astype(PDTYPE),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), PDTYPE)
        p["bk"] = jnp.zeros((K * hd,), PDTYPE)
        p["bv"] = jnp.zeros((K * hd,), PDTYPE)
    return p


def _project_qkv(p: dict, x: jax.Array, cfg: ModelConfig):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = shard(q.reshape(B, S, H, hd), "batch", "seq", "heads", None)
    k = k.reshape(B, S, K, hd)
    v = v.reshape(B, S, K, hd)
    return q, k, v


def _sketched_two_span(o, qg, kt, vt, win, tail, sketch, scale):
    """Two-span long-context attention (serve/kv_sketch.py).

    ``o`` is the exact-path attention output (B, Sq, K, R, hd) computed
    with the legacy full causal mask; ``win`` is the exact-window
    visibility mask (folded positions excluded), broadcastable to the
    (B, K, R, Sq, Sk) score tensor.  Computes f32 online-softmax
    statistics over the window span, queries the slot's FCS tail tables
    for the folded span, merges the two, and selects the merged output
    ONLY for slots that have folded anything — slots with fold_base == 0
    keep ``o`` verbatim (elementwise where), which is the bitwise
    regression anchor: window >= context runs match a sketch-free engine
    exactly.  The window span always contains the query's own position,
    so its statistics are never empty."""
    # deferred: serve/__init__ -> engine -> scheduler -> transformer ->
    # moe -> layers would otherwise cycle at import time
    from repro.serve import kv_sketch as _kvs
    fold_base = sketch["fold_base"]
    sw = jnp.einsum("bqkrh,bskh->bkrqs", qg, kt).astype(jnp.float32) * scale
    sw = jnp.where(win, sw, -1e30)
    m_e, l_e, acc_e = _kvs.exact_span_stats(sw, vt, win)
    m_t, l_t, acc_t = _kvs.tail_attend(qg, tail["k"], tail["v"],
                                       sketch["onehot"], fold_base, scale)
    merged = _kvs.merge_spans(m_e, l_e, acc_e, m_t, l_t, acc_t)
    merged = merged.transpose(0, 3, 1, 2, 4).astype(o.dtype)  # (B,Sq,K,R,hd)
    sel = (fold_base > 0)[:, None, None, None, None]
    return jnp.where(sel, merged, o)


def _kernel_paged_attention(qg, k, v, tables, start, tail, sketch):
    """Flash-decode kernel path shared by the three paged shapes
    (kernels/paged_attention.py): attend straight through the block
    table — the dense gathered KV copy never materializes.  qg:
    (B, Sq, K, R, hd); k/v: the updated (NB, bs, K, hd) pools; start:
    (B,) position of each slot's query row 0.  With ``tail``/``sketch``
    the kernel covers the exact window [fold_base, start + i] and the
    FCS tail supplies the folded span, merged with online-softmax
    statistics; slots with fold_base == 0 keep the pure kernel output
    bitwise (same anchor as _sketched_two_span).  Returns
    (B, Sq, K, R, hd) in qg's dtype."""
    B = qg.shape[0]
    fb = (sketch["fold_base"] if tail is not None
          else jnp.zeros((B,), jnp.int32))
    m_e, l_e, acc_e = kops.paged_attention_op(qg, k, v, tables, start, fb,
                                              use_pallas=True)
    o = acc_e / jnp.maximum(l_e, 1e-30)[..., None]      # (B,K,R,Sq,hd)
    if tail is not None:
        from repro.serve import kv_sketch as _kvs
        scale = 1.0 / math.sqrt(qg.shape[-1])
        m_t, l_t, acc_t = _kvs.tail_attend(qg, tail["k"], tail["v"],
                                           sketch["onehot"], fb, scale)
        merged = _kvs.merge_spans(m_e, l_e, acc_e, m_t, l_t, acc_t)
        o = jnp.where((fb > 0)[:, None, None, None, None], merged, o)
    return o.transpose(0, 3, 1, 2, 4).astype(qg.dtype)  # (B,Sq,K,R,hd)


def _gqa_scores_softmax_out(q, k, v, mask, scale):
    """q: (B,Sq,K,R,hd); k,v: (B,Sk,K,hd); mask: bool, broadcastable to
    the (B,K,R,Sq,Sk) score tensor, or None.
    Grouped form used on the decode path (reads each KV head once)."""
    s = jnp.einsum("bqkrh,bskh->bkrqs", q, k).astype(jnp.float32) * scale
    if mask is not None:
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bkrqs,bskh->bqkrh", p, v)


def causal_attention(p: dict, x: jax.Array, cfg: ModelConfig,
                     positions: jax.Array, kv_chunk: int = 1024) -> jax.Array:
    """Full-sequence causal attention: online-softmax (flash-style) over KV
    chunks, scanned.  Query rows stay fully data/context-sharded — every
    device participates in every KV-chunk iteration (KV is replicated /
    all-gathered, which is cheap for GQA), so context sharding of the
    sequence never serializes the scan.  Per-chunk bodies rematerialize in
    the backward."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads
    R = H // K
    q, k, v = _project_qkv(p, x, cfg)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    # replicate KV at the (small) K-head stage: the context-sharded seq dim
    # is all-gathered here, BEFORE the R-fold head broadcast
    k = shard(k, "batch", "kv_seq", None, None)
    v = shard(v, "batch", "kv_seq", None, None)
    if R > 1:
        k = jnp.repeat(k, R, axis=2)
        v = jnp.repeat(v, R, axis=2)
    o = _flash_attention(q, k, v, min(kv_chunk, S))
    o = o.reshape(B, S, H * hd)
    o = shard(o, "batch", "seq", None)
    return jnp.einsum("bsh,hd->bsd", o, p["wo"])


def _flash_attention(q, k, v, kc):
    """Online-softmax causal attention.  q,k,v: (B,S,H,hd) (kv already
    expanded to H heads).  Scans KV chunks of size kc; the causal mask is
    applied per chunk.  f32 running (max, sum, acc) statistics."""
    B, S, H, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    pad = (-S) % kc
    kp, vp = k, v
    if pad:  # padded keys are masked out by the causal test below
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nk = (S + pad) // kc
    kg = kp.reshape(B, nk, kc, H, hd).transpose(1, 0, 2, 3, 4)
    vg = vp.reshape(B, nk, kc, H, hd).transpose(1, 0, 2, 3, 4)
    q_pos = jnp.arange(S)

    def _pin(m, l, acc):  # keep scan carries on the q/context sharding
        m = shard(m, "batch", None, "seq")
        l = shard(l, "batch", None, "seq")
        acc = shard(acc, "batch", "seq", None, None)
        return m, l, acc

    @jax.checkpoint
    def chunk_fn(carry, inp):
        m, l, acc = carry                       # (B,H,S), (B,H,S), (B,S,H,hd)
        cj, kj, vj = inp
        k_pos = cj * kc + jnp.arange(kc)
        mask = q_pos[:, None] >= k_pos[None, :]              # (S, kc)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kj).astype(jnp.float32) * scale
        s = jnp.where(mask[None, None], s, -1e30)
        s = shard(s, "batch", None, "seq", None)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr.transpose(0, 2, 1)[..., None] + jnp.einsum(
            "bhqk,bkhd->bqhd", p.astype(vj.dtype), vj).astype(jnp.float32)
        return _pin(m_new, l, acc), None

    m0 = jnp.full((B, H, S), -1e30, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    a0 = jnp.zeros((B, S, H, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(chunk_fn, _pin(m0, l0, a0),
                                  (jnp.arange(nk), kg, vg))
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


def _project_qkv_rope(p: dict, x: jax.Array, cfg: ModelConfig,
                      positions: jax.Array):
    """Shared decode/chunk-prefill QKV block: project (+bias), split
    heads, rope q and k at ``positions`` ((S,) or (B, S)).  One home for
    this math keeps the chunked-prefill path bit-identical to decode."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    kn = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    vn = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q, kn, vn = q + p["bq"], kn + p["bk"], vn + p["bv"]
    q = rope(q.reshape(B, S, H, hd), positions, cfg.rope_theta)
    kn = rope(kn.reshape(B, S, K, hd), positions, cfg.rope_theta)
    return q, kn, vn.reshape(B, S, K, hd)


def decode_attention(p: dict, x: jax.Array, cfg: ModelConfig,
                     cache: dict, index: jax.Array,
                     tables: Optional[jax.Array] = None,
                     tail: Optional[dict] = None,
                     sketch: Optional[dict] = None,
                     use_kernel: Optional[bool] = None
                     ) -> Tuple[jax.Array, dict]:
    """Single-token decode against a KV cache.

    Dense mode (``tables=None``): cache {"k": (B, S_max, K, hd), "v": ...};
    ``index`` is the current position — a scalar (whole batch at the same
    position, the classic synchronized-decode path) or a (B,) vector of
    per-slot positions (the continuous-batching path: every slot writes
    its KV row at its own position and attends under its own causal mask).

    Paged mode (``tables`` given): cache is the shared block pool
    {"k": (num_blocks, block_size, K, hd), "v": ...} and ``tables`` is the
    (B, blocks_per_slot) int32 block table mapping each slot's logical
    block index to a physical pool block (entries == num_blocks are
    unallocated).  Each slot's new KV row scatters into
    table[pos // bs][pos % bs] (out-of-range physical ids are dropped, so
    retired slots with invalidated tables write nowhere), and the slot
    attends over its gathered blocks under the same per-slot causal mask.
    Returns (out (B,1,d), updated cache).
    """
    if tables is not None:
        return _paged_decode_attention(p, x, cfg, cache, index, tables,
                                       tail, sketch, use_kernel)
    B, one, _ = x.shape
    hd = cfg.resolved_head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads
    R = H // K
    per_slot = jnp.ndim(index) == 1
    pos = (index[:, None].astype(jnp.int32) if per_slot
           else jnp.full((1,), index, jnp.int32))
    q, kn, vn = _project_qkv_rope(p, x, cfg, pos)
    if per_slot:
        slots = jnp.arange(B, dtype=jnp.int32)
        k = cache["k"].at[slots, index].set(kn[:, 0].astype(cache["k"].dtype))
        v = cache["v"].at[slots, index].set(vn[:, 0].astype(cache["v"].dtype))
    else:
        k = jax.lax.dynamic_update_slice(
            cache["k"], kn.astype(cache["k"].dtype), (0, index, 0, 0))
        v = jax.lax.dynamic_update_slice(
            cache["v"], vn.astype(cache["v"].dtype), (0, index, 0, 0))
    k = shard(k, "batch", "kv_seq", "kv_heads", None)
    v = shard(v, "batch", "kv_seq", "kv_heads", None)
    S = k.shape[1]
    qg = q.reshape(B, 1, K, R, hd)
    if per_slot:
        mask = (jnp.arange(S)[None, :] <= index[:, None]
                )[:, None, None, None, :]                # (B,1,1,1,S)
    else:
        mask = (jnp.arange(S) <= index)[None, :]         # (1,S) -> broadcast
    o = _gqa_scores_softmax_out(qg, k, v, mask, 1.0 / math.sqrt(hd))
    o = o.reshape(B, 1, H * hd)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"])
    return out, {"k": k, "v": v}


def _paged_decode_attention(p: dict, x: jax.Array, cfg: ModelConfig,
                            cache: dict, index: jax.Array,
                            tables: jax.Array,
                            tail: Optional[dict] = None,
                            sketch: Optional[dict] = None,
                            use_kernel: Optional[bool] = None
                            ) -> Tuple[jax.Array, dict]:
    """Paged single-token decode: scatter each slot's new KV row through
    its block table, then attend — in one flash-decode Pallas pass over
    the table (``use_kernel``, default on TPU) or by gathering the
    slot's blocks dense and softmaxing in jnp (the oracle path, default
    elsewhere).  See decode_attention.
    With ``tail``/``sketch`` (serve/kv_sketch.py) the attention becomes
    two-span: exact over [fold_base, index], sketched over [0, fold_base)."""
    if use_kernel is None:
        use_kernel = kops.default_use_pallas()
    B, _, _ = x.shape
    hd = cfg.resolved_head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads
    R = H // K
    NB, bs = cache["k"].shape[0], cache["k"].shape[1]
    nb_slot = tables.shape[1]
    pos = index[:, None].astype(jnp.int32)
    q, kn, vn = _project_qkv_rope(p, x, cfg, pos)
    blk = (index // bs).astype(jnp.int32)
    phys = jnp.take_along_axis(tables, blk[:, None], axis=1)[:, 0]  # (B,)
    off = (index % bs).astype(jnp.int32)
    # unallocated/invalidated table entries hold NB: the scatter drops the
    # write, so an inactive slot's idle decode step mutates nothing — pool
    # blocks can be freed and reused the moment their refcount hits zero.
    k = cache["k"].at[phys, off].set(
        kn[:, 0].astype(cache["k"].dtype), mode="drop")
    v = cache["v"].at[phys, off].set(
        vn[:, 0].astype(cache["v"].dtype), mode="drop")
    k = shard(k, "kv_blocks", None, "kv_heads", None)
    v = shard(v, "kv_blocks", None, "kv_heads", None)
    qg = q.reshape(B, 1, K, R, hd)
    scale = 1.0 / math.sqrt(hd)
    if use_kernel:
        o = _kernel_paged_attention(qg, k, v, tables,
                                    index.astype(jnp.int32), tail, sketch)
    else:
        # gather the slot's logical KV row; invalid blocks read as zeros
        # and sit at positions the per-slot causal mask never exposes
        kt = jnp.take(k, tables, axis=0, mode="fill", fill_value=0)
        vt = jnp.take(v, tables, axis=0, mode="fill", fill_value=0)
        S = nb_slot * bs
        kt = shard(kt.reshape(B, S, K, hd), "batch", "kv_seq", "kv_heads",
                   None)
        vt = shard(vt.reshape(B, S, K, hd), "batch", "kv_seq", "kv_heads",
                   None)
        mask = (jnp.arange(S)[None, :] <= index[:, None]
                )[:, None, None, None, :]                # (B,1,1,1,S)
        o = _gqa_scores_softmax_out(qg, kt, vt, mask, scale)
        if tail is not None:
            win = mask & (jnp.arange(S)[None, :] >=
                          sketch["fold_base"][:, None])[:, None, None,
                                                        None, :]
            o = _sketched_two_span(o, qg, kt, vt, win, tail, sketch, scale)
    o = o.reshape(B, 1, H * hd)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"])
    return out, {"k": k, "v": v}


def verify_attention(p: dict, x: jax.Array, cfg: ModelConfig,
                     cache: dict, index: jax.Array, tables: jax.Array,
                     tail: Optional[dict] = None,
                     sketch: Optional[dict] = None,
                     use_kernel: Optional[bool] = None
                     ) -> Tuple[jax.Array, dict]:
    """Multi-query paged decode (speculative verify).

    x: (B, C, d) — every slot feeds C tokens (its last committed token
    followed by C-1 draft proposals) at absolute positions
    index[b] .. index[b] + C - 1.  Structurally this is ``chunk_attention``
    batched over slots: each slot's C new KV rows scatter through its own
    block-table row (out-of-range or invalidated physical ids drop, so
    retired slots and overhang rows mutate nothing), then each of its C
    queries attends causally — key row j visible to query i iff
    j <= index[b] + i — over the slot's gathered blocks.  The per-row
    projections and masks match single-token paged decode exactly, so a
    verified-and-accepted position produces the same logits a plain
    decode step at that position would.  Returns (out (B, C, d), pool).
    """
    B, C, _ = x.shape
    hd = cfg.resolved_head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads
    R = H // K
    NB, bs = cache["k"].shape[0], cache["k"].shape[1]
    nb_slot = tables.shape[1]
    positions = (index[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
                 ).astype(jnp.int32)                     # (B, C)
    q, kn, vn = _project_qkv_rope(p, x, cfg, positions)
    blk = positions // bs
    phys = jnp.take_along_axis(tables, jnp.minimum(blk, nb_slot - 1),
                               axis=1)
    # rows past the slot's table (speculative overhang at max_seq) must
    # DROP, not clamp into the last reserved block
    phys = jnp.where(blk < nb_slot, phys, NB)
    off = positions % bs
    k = cache["k"].at[phys, off].set(
        kn.astype(cache["k"].dtype), mode="drop")
    v = cache["v"].at[phys, off].set(
        vn.astype(cache["v"].dtype), mode="drop")
    k = shard(k, "kv_blocks", None, "kv_heads", None)
    v = shard(v, "kv_blocks", None, "kv_heads", None)
    qg = q.reshape(B, C, K, R, hd)
    scale = 1.0 / math.sqrt(hd)
    if use_kernel is None:
        use_kernel = kops.default_use_pallas()
    if use_kernel:
        # kernel row i of slot b sees key positions <= index[b] + i —
        # identical per-row math to a single-token decode at that
        # position, the bitwise spec-identity anchor
        o = _kernel_paged_attention(qg, k, v, tables,
                                    index.astype(jnp.int32), tail, sketch)
    else:
        kt = jnp.take(k, tables, axis=0, mode="fill", fill_value=0)
        vt = jnp.take(v, tables, axis=0, mode="fill", fill_value=0)
        S = nb_slot * bs
        kt = shard(kt.reshape(B, S, K, hd), "batch", "kv_seq", "kv_heads",
                   None)
        vt = shard(vt.reshape(B, S, K, hd), "batch", "kv_seq", "kv_heads",
                   None)
        mask = (jnp.arange(S)[None, None, :] <= positions[:, :, None]
                )[:, None, None]                         # (B,1,1,C,S)
        o = _gqa_scores_softmax_out(qg, kt, vt, mask, scale)
        if tail is not None:
            win = mask & (jnp.arange(S)[None, :] >=
                          sketch["fold_base"][:, None])[:, None, None,
                                                        None, :]
            o = _sketched_two_span(o, qg, kt, vt, win, tail, sketch, scale)
    o = o.reshape(B, C, H * hd)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"])
    return out, {"k": k, "v": v}


def chunk_attention(p: dict, x: jax.Array, cfg: ModelConfig,
                    cache: dict, table: jax.Array, start: jax.Array,
                    tail: Optional[dict] = None,
                    sketch: Optional[dict] = None,
                    use_kernel: Optional[bool] = None
                    ) -> Tuple[jax.Array, dict]:
    """Multi-token chunk against the paged slot KV (chunked prefill).

    x: (1, C, d) — one prompt chunk for one slot.  ``cache`` is the shared
    block pool {"k": (num_blocks, block_size, K, hd), "v": ...} and
    ``table`` the slot's (blocks_per_slot,) block-table row.  KV rows for
    absolute positions [start, start + C) scatter into the slot's blocks
    (rows mapping to unallocated table entries — e.g. tail-chunk zero
    padding beyond the request's reserved blocks — are dropped), then every
    chunk query attends causally against the slot's gathered blocks, so a
    chunk at offset ``start`` sees both earlier chunks and any shared
    prefix blocks referenced by the table.  ``table`` and ``start`` are
    traced — one compilation serves every slot and offset.
    Returns (out (1, C, d), updated pool).
    """
    _, C, _ = x.shape
    hd = cfg.resolved_head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads
    R = H // K
    NB, bs = cache["k"].shape[0], cache["k"].shape[1]
    nb_slot = table.shape[0]
    positions = start + jnp.arange(C, dtype=jnp.int32)
    q, kn, vn = _project_qkv_rope(p, x, cfg, positions)
    phys = jnp.take(table, positions // bs, mode="fill", fill_value=NB)
    k = cache["k"].at[phys, positions % bs].set(
        kn[0].astype(cache["k"].dtype), mode="drop")
    v = cache["v"].at[phys, positions % bs].set(
        vn[0].astype(cache["v"].dtype), mode="drop")
    # same placement pin decode applies: the pool layout from
    # serve_state_pspecs must survive the chunked-prefill update
    k = shard(k, "kv_blocks", None, "kv_heads", None)
    v = shard(v, "kv_blocks", None, "kv_heads", None)
    qg = q.reshape(1, C, K, R, hd)
    scale = 1.0 / math.sqrt(hd)
    if use_kernel is None:
        use_kernel = kops.default_use_pallas()
    if use_kernel:
        o = _kernel_paged_attention(qg, k, v, table[None],
                                    jnp.reshape(start, (1,)).astype(
                                        jnp.int32), tail, sketch)
    else:
        ks = jnp.take(k, table, axis=0, mode="fill", fill_value=0)
        vs = jnp.take(v, table, axis=0, mode="fill", fill_value=0)
        S = nb_slot * bs
        ks = ks.reshape(1, S, K, hd)
        vs = vs.reshape(1, S, K, hd)
        # causal over absolute positions: key row j visible to chunk
        # query i iff j <= start + i (earlier chunks / shared prefix
        # blocks included)
        mask = (jnp.arange(S)[None, :] <= positions[:, None]
                )[None, None, None]
        o = _gqa_scores_softmax_out(qg, ks, vs, mask, scale)
        if tail is not None:
            win = mask & (jnp.arange(S)[None, :] >=
                          sketch["fold_base"][:, None])[:, None, None,
                                                        None, :]
            o = _sketched_two_span(o, qg, ks, vs, win, tail, sketch, scale)
    o = o.reshape(1, C, H * hd)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"])
    return out, {"k": k, "v": v}


def init_kv_cache(cfg: ModelConfig, batch: int, max_seq: int,
                  dtype=jnp.bfloat16) -> dict:
    hd = cfg.resolved_head_dim
    K = cfg.num_kv_heads
    z = jnp.zeros((batch, max_seq, K, hd), dtype)
    return {"k": z, "v": z}


def init_paged_kv_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                        dtype=jnp.bfloat16) -> dict:
    """Per-layer paged KV block pool: physical blocks are position-free
    storage; a slot's block table gives them logical order."""
    hd = cfg.resolved_head_dim
    K = cfg.num_kv_heads
    z = jnp.zeros((num_blocks, block_size, K, hd), dtype)
    return {"k": z, "v": z}


# ---------------------------------------------------------------------------
# GLU FFN
# ---------------------------------------------------------------------------


def init_glu_ffn(key: jax.Array, d: int, ff: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": (jax.random.normal(k1, (d, ff)) / math.sqrt(d)).astype(PDTYPE),
        "w_up": (jax.random.normal(k2, (d, ff)) / math.sqrt(d)).astype(PDTYPE),
        "w_down": (jax.random.normal(k3, (ff, d)) / math.sqrt(ff)).astype(PDTYPE),
    }


def _act(name: str):
    return jax.nn.gelu if name == "gelu" else jax.nn.silu


def glu_ffn(p: dict, x: jax.Array, act: str) -> jax.Array:
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    h = _act(act)(g) * u
    h = shard(h, "batch", "seq", "ff")
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


# ---------------------------------------------------------------------------
# Embedding + LM head (dense or FCS-sketched)
# ---------------------------------------------------------------------------


def init_embedding(key: jax.Array, cfg: ModelConfig) -> jax.Array:
    return (jax.random.normal(key, (cfg.padded_vocab, cfg.d_model)) * 0.02
            ).astype(PDTYPE)


def embed_tokens(emb: jax.Array, tokens: jax.Array) -> jax.Array:
    x = jnp.take(emb, tokens, axis=0)
    return shard(x, "batch", "seq", "embed")


def head_sketch_len(cfg: ModelConfig) -> int:
    return cfg.sketch.head_hash_len or cfg.d_model // 4


def init_head(key: jax.Array, cfg: ModelConfig) -> Optional[jax.Array]:
    if cfg.sketch.sketched_head:
        # FCS-sketched LM head (paper Section 4.2, CP-TRL): the projection is
        # trained directly in the J~-dim sketch space; activations are
        # count-sketched per token (FCS degenerates to CS for order-1
        # activations).  CR = d_model / J~.
        J = head_sketch_len(cfg)
        return (jax.random.normal(key, (J, cfg.padded_vocab))
                / math.sqrt(J)).astype(PDTYPE)
    if cfg.tie_embeddings:
        return None
    return (jax.random.normal(key, (cfg.d_model, cfg.padded_vocab))
            / math.sqrt(cfg.d_model)).astype(PDTYPE)


@functools.lru_cache(maxsize=None)
def _head_hash_tables(seed: int, d: int, J: int):
    """Host-side (trace-safe) 2-wise-independent hash tables."""
    import numpy as np
    from repro.core.hashes import PRIME
    rng = np.random.RandomState(seed & 0x7FFFFFFF)
    ah, bh = rng.randint(1, PRIME, dtype=np.int64), rng.randint(0, PRIME, dtype=np.int64)
    as_, bs = rng.randint(1, PRIME, dtype=np.int64), rng.randint(0, PRIME, dtype=np.int64)
    idx = np.arange(d, dtype=np.int64)
    h = (((ah * idx + bh) % PRIME) % J).astype(np.int32)
    sg = (1.0 - 2.0 * (((as_ * idx + bs) % PRIME) % 2)).astype(np.float32)
    return h, sg


def _head_io(params: dict, x: jax.Array, cfg: ModelConfig):
    """Returns (x_eff, W_eff) for the vocab projection, applying the FCS
    activation sketch when the sketched head is enabled."""
    if cfg.sketch.sketched_head:
        J = head_sketch_len(cfg)
        h, sg = _head_hash_tables(cfg.sketch.seed, cfg.d_model, J)
        onehot = (jax.nn.one_hot(h, J, dtype=x.dtype)
                  * sg[:, None].astype(x.dtype))
        xs = jnp.einsum("bsd,dj->bsj", x, onehot)
        return xs, params["head"]
    head = params["head"] if params.get("head") is not None \
        else params["embed"].T
    return x, head


def logits_fn(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Final-hidden -> vocab logits (f32)."""
    x, head = _head_io(params, x, cfg)
    logits = jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)
    return shard(logits, "batch", "seq", "vocab")


def cross_entropy(params: dict, x: jax.Array, labels: jax.Array,
                  cfg: ModelConfig, vocab_chunk: int = 8192) -> jax.Array:
    """Vocab-chunked online-logsumexp CE: the (B, S, V) f32 logits never
    fully materialize, and the chunked axis is the (replicated) vocab, so
    data/context sharding of tokens never serializes the scan.  Padded vocab
    rows carry random-init weights; they only add a handful of terms to the
    logsumexp (trained to -inf naturally) and are never produced as labels."""
    B, S, _ = x.shape
    x, head = _head_io(params, x, cfg)
    V = head.shape[-1]
    vc = min(vocab_chunk, V)
    pad = (-V) % vc
    if pad:
        head = jnp.pad(head, ((0, 0), (0, pad)))
    nv = (V + pad) // vc
    hg = head.reshape(-1, nv, vc).transpose(1, 0, 2)    # (nv, d, vc)

    @jax.checkpoint
    def chunk(carry, inp):
        m, l, gold = carry                              # (B,S),(B,S),(B,S)
        cj, hj = inp
        logits = jnp.einsum("bsd,dv->bsv", x, hj).astype(jnp.float32)
        if pad:  # mask out padded columns in the final chunk
            col = cj * vc + jnp.arange(vc)
            logits = jnp.where(col[None, None, :] < V, logits, -1e30)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        l = l * jnp.exp(m - m_new) + jnp.sum(
            jnp.exp(logits - m_new[..., None]), axis=-1)
        idx = labels - cj * vc
        valid = (idx >= 0) & (idx < vc)
        g = jnp.take_along_axis(logits, jnp.clip(idx, 0, vc - 1)[..., None],
                                axis=-1)[..., 0]
        gold = gold + jnp.where(valid, g, 0.0)
        return (m_new, l, gold), None

    m0 = jnp.full((B, S), -1e30, jnp.float32)
    z0 = jnp.zeros((B, S), jnp.float32)
    (m, l, gold), _ = jax.lax.scan(chunk, (m0, z0, z0), (jnp.arange(nv), hg))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    return jnp.mean(lse - gold)
