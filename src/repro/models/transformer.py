"""Model assembly: scan-over-layers stacks for all five families, with
train / prefill / decode paths sharing the same per-block code.

Param tree layout (family-dependent "blocks" subtree; all per-layer leaves
stacked on a leading L axis so the stack lowers to one lax.scan):

  {"embed": (Vp, d), "head": (d, Vp)|None, "final_norm": (d,), "blocks": ...}

Caches:
  dense/moe/audio/vlm : {"kv": {"k": (L,B,Smax,K,hd), "v": ...}} (dense) or
                        {"kv": {"k": (L,NB,bs,K,hd), "v": ...}} block pool
                        indexed through per-slot block tables (paged serve)
  ssm (xlstm)         : {"mlstm": <stacked states>, "slstm": <stacked states>}
  hybrid (zamba2)     : {"mamba": <stacked>, "shared_kv": (G,B,Smax,K,hd)x2}
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as ly
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.sharding import shard

Params = Dict[str, Any]


def _stack_init(key: jax.Array, n: int, init_fn):
    return jax.vmap(init_fn)(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_params(key: jax.Array, cfg: ModelConfig) -> Params:
    k_emb, k_head, k_blocks = jax.random.split(key, 3)
    params: Params = {
        "embed": ly.init_embedding(k_emb, cfg),
        "head": ly.init_head(k_head, cfg),
        "final_norm": ly.init_rms_norm(cfg.d_model),
        "blocks": _init_blocks(k_blocks, cfg),
    }
    return params


def _init_blocks(key: jax.Array, cfg: ModelConfig) -> Params:
    L, d = cfg.num_layers, cfg.d_model
    if cfg.family in ("dense", "audio", "vlm"):
        k1, k2 = jax.random.split(key)
        return {
            "attn": _stack_init(k1, L, lambda k: ly.init_attention(k, cfg)),
            "ffn": _stack_init(k2, L, lambda k: ly.init_glu_ffn(k, d, cfg.d_ff)),
            "norm1": jnp.zeros((L, d), ly.PDTYPE),
            "norm2": jnp.zeros((L, d), ly.PDTYPE),
        }
    if cfg.family == "moe":
        k1, k2 = jax.random.split(key)
        return {
            "attn": _stack_init(k1, L, lambda k: ly.init_attention(k, cfg)),
            "moe": _stack_init(k2, L, lambda k: moe_mod.init_moe(k, cfg)),
            "norm1": jnp.zeros((L, d), ly.PDTYPE),
            "norm2": jnp.zeros((L, d), ly.PDTYPE),
        }
    if cfg.family == "ssm":
        x = cfg.xlstm
        G = L // (x.m_per_group + x.s_per_group)
        n_m, n_s = G * x.m_per_group, G * x.s_per_group
        k1, k2 = jax.random.split(key)
        return {
            "mlstm": _stack_init(k1, n_m, lambda k: ssm_mod.init_mlstm(k, cfg)),
            "slstm": _stack_init(k2, n_s, lambda k: ssm_mod.init_slstm(k, cfg)),
        }
    if cfg.family == "hybrid":
        hb = cfg.hybrid
        G = L // hb.mamba_per_group
        k1, k2, k3 = jax.random.split(key, 3)

        def init_shared(k):
            ka, kf = jax.random.split(k)
            return {
                "attn": ly.init_attention(ka, cfg),
                "ffn": ly.init_glu_ffn(kf, d, cfg.d_ff),
                "norm1": jnp.zeros((d,), ly.PDTYPE),
                "norm2": jnp.zeros((d,), ly.PDTYPE),
            }

        return {
            "mamba": _stack_init(k1, L, lambda k: ssm_mod.init_mamba2(k, cfg)),
            "shared": _stack_init(k2, hb.num_shared_blocks, init_shared),
        }
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# Block application helpers
# ---------------------------------------------------------------------------


def _dense_block(p_l, x, cfg: ModelConfig, positions, cache_l, index, mode,
                 tables=None, tail_l=None, sketch=None, kernels=None):
    """One attention+FFN (or attention+MoE) block. Returns (x, aux, cache).
    ``tail_l``/``sketch``: per-layer FCS tail tables + fold state for
    two-span long-context decode (serve/kv_sketch.py); read-only here.
    ``kernels``: paged modes — True routes attention through the
    flash-decode Pallas kernel, False through the jnp gather path, None
    auto-detects (kernel on TPU)."""
    h = ly.rms_norm(x, p_l["norm1"], cfg.norm_eps)
    new_cache = None
    if mode == "decode":
        a, new_cache = ly.decode_attention(p_l["attn"], h, cfg, cache_l,
                                           index, tables=tables,
                                           tail=tail_l, sketch=sketch,
                                           use_kernel=kernels)
    elif mode == "verify":
        a, new_cache = ly.verify_attention(p_l["attn"], h, cfg, cache_l,
                                           index, tables, tail=tail_l,
                                           sketch=sketch,
                                           use_kernel=kernels)
    elif mode == "chunk":
        a, new_cache = ly.chunk_attention(p_l["attn"], h, cfg, cache_l,
                                          tables, index, tail=tail_l,
                                          sketch=sketch,
                                          use_kernel=kernels)
    else:
        a = ly.causal_attention(p_l["attn"], h, cfg, positions)
        if mode == "prefill":
            # re-derive roped k/v for the cache (cheap vs attention itself)
            q, k, v = ly._project_qkv(p_l["attn"], h, cfg)
            del q
            k = ly.rope(k, positions, cfg.rope_theta)
            new_cache = {"k": k, "v": v}
    x = x + a
    h = ly.rms_norm(x, p_l["norm2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "moe":
        f, aux = moe_mod.moe_ffn(p_l["moe"], h, cfg)
    else:
        f = ly.glu_ffn(p_l["ffn"], h, cfg.act)
    return x + f, aux, new_cache


# ---------------------------------------------------------------------------
# Forward (all modes)
# ---------------------------------------------------------------------------


def forward(params: Params, x: jax.Array, cfg: ModelConfig,
            mode: str = "train", cache: Optional[dict] = None,
            index: Optional[jax.Array] = None,
            tables: Optional[jax.Array] = None,
            sketch: Optional[dict] = None,
            kernels: Optional[bool] = None
            ) -> Tuple[jax.Array, jax.Array, Optional[dict]]:
    """x: embedded inputs (B, S, d).  Returns (hidden, aux_loss, cache).

    ``sketch`` (attention families, paged modes only): {"fold_base": (B,)
    int32, "onehot": (Z, T, C)} — enables two-span decode against the
    cache's "tail" FCS tables (serve/kv_sketch.py).

    ``kernels`` (paged modes only): static attention-implementation
    switch — True runs the flash-decode paged Pallas kernel
    (kernels/paged_attention.py; interpret mode off-TPU), False the jnp
    gather-then-softmax path, None auto-detects (kernel on TPU).

    Modes: "train" / "prefill" (full-sequence), "decode" (single token per
    slot against the cache — paged through per-slot block ``tables`` when
    given, dense otherwise), "chunk" (multi-token prompt chunk written
    into the paged pool through the slot's (blocks_per_slot,) ``tables``
    row at offset ``index`` — the chunked prefill building block;
    attention families only), "verify" (speculative decode: every slot
    feeds S tokens at per-slot start positions ``index`` ((B,)) through
    its block-table row — multi-query paged decode; attention families
    only).
    """
    B, S, d = x.shape
    if mode not in ("decode", "chunk", "verify"):
        x = shard(x, "batch", "residual", None)
    if mode == "verify":
        positions = None     # per-slot (B,) starts; handled in-layer
    else:
        positions = (jnp.arange(S) if index is None
                     else jnp.arange(S) + index)
    fam = cfg.family
    if fam in ("dense", "audio", "vlm", "moe"):
        y, aux, new_cache = _forward_attn_stack(params, x, cfg, positions,
                                                mode, cache, index, tables,
                                                sketch, kernels)
    elif mode in ("chunk", "verify"):
        raise ValueError(f"mode {mode!r} needs a kv-cache family, "
                         f"got {fam!r}")
    elif fam == "ssm":
        y, aux, new_cache = _forward_xlstm(params, x, cfg, mode, cache)
    elif fam == "hybrid":
        y, aux, new_cache = _forward_zamba(params, x, cfg, positions, mode,
                                           cache, index)
    else:
        raise ValueError(fam)
    y = ly.rms_norm(y, params["final_norm"], cfg.norm_eps)
    return y, aux, new_cache


def _forward_attn_stack(params, x, cfg, positions, mode, cache, index,
                        tables=None, sketch=None, kernels=None):
    blocks = params["blocks"]

    if mode in ("decode", "chunk", "verify"):
        sketched = sketch is not None and "tail" in (cache or {})

        def body(carry, xs):
            h, aux = carry
            p_l, c_l = xs[0], xs[1]
            t_l = xs[2] if sketched else None
            h, a, nc = _dense_block(p_l, h, cfg, positions, c_l, index, mode,
                                    tables, tail_l=t_l, sketch=sketch,
                                    kernels=kernels)
            return (h, aux + a), nc

        xs = ((blocks, cache["kv"], cache["tail"]) if sketched
              else (blocks, cache["kv"]))
        (y, aux), kv = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                    xs)
        new_cache = {"kv": kv}
        if "tail" in (cache or {}):
            # tail tables are read-only inside the stack (folds happen in
            # the serve chunk, outside forward) — reattach unchanged
            new_cache["tail"] = cache["tail"]
        return y, aux, new_cache

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def body(carry, p_l):
        h, aux = carry
        h, a, nc = _dense_block(p_l, h, cfg, positions, None, index, mode)
        h = shard(h, "batch", "residual", None)
        return (h, aux + a), nc

    (y, aux), kv = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), blocks)
    new_cache = {"kv": kv} if mode == "prefill" else None
    return y, aux, new_cache


def _forward_xlstm(params, x, cfg, mode, cache):
    xl = cfg.xlstm
    G = cfg.num_layers // (xl.m_per_group + xl.s_per_group)
    m_per, s_per = xl.m_per_group, xl.s_per_group
    blocks = params["blocks"]
    # reshape stacked (n_m, ...) -> (G, m_per, ...)
    ml = jax.tree.map(lambda a: a.reshape(G, m_per, *a.shape[1:]),
                      blocks["mlstm"])
    sl = jax.tree.map(lambda a: a.reshape(G, s_per, *a.shape[1:]),
                      blocks["slstm"])
    want_state = mode in ("prefill", "decode")
    m_state = s_state = None
    if mode == "decode":
        m_state = jax.tree.map(
            lambda a: a.reshape(G, m_per, *a.shape[1:]), cache["mlstm"])
        s_state = jax.tree.map(
            lambda a: a.reshape(G, s_per, *a.shape[1:]), cache["slstm"])

    def group(carry, xs):
        h = carry
        p_m, p_s = xs[0], xs[1]
        st_m = xs[2] if mode == "decode" else None
        st_s = xs[3] if mode == "decode" else None

        def m_body(hh, mxs):
            p_i = mxs[0]
            st_i = mxs[1] if mode == "decode" else None
            out, ns = ssm_mod.mlstm_block(
                p_i, hh, cfg, state=st_i, q_chunk=512,
                want_state=(mode == "prefill"))
            return hh + out, ns

        def s_body(hh, sxs):
            p_i = sxs[0]
            st_i = sxs[1] if mode == "decode" else None
            out, ns = ssm_mod.slstm_block(
                p_i, hh, cfg, state=st_i, want_state=(mode == "prefill"))
            return hh + out, ns

        if mode == "train":
            m_body = jax.checkpoint(m_body)
            s_body = jax.checkpoint(s_body)
        h, m_ns = jax.lax.scan(m_body, h,
                               (p_m, st_m) if mode == "decode" else (p_m,))
        h, s_ns = jax.lax.scan(s_body, h,
                               (p_s, st_s) if mode == "decode" else (p_s,))
        if mode != "decode":
            h = shard(h, "batch", "residual", None)
        return h, (m_ns, s_ns)

    xs = (ml, sl) if mode != "decode" else (ml, sl, m_state, s_state)
    y, (m_ns, s_ns) = jax.lax.scan(group, x, xs)
    new_cache = None
    if want_state and m_ns is not None:
        flat = lambda t: jax.tree.map(
            lambda a: a.reshape(a.shape[0] * a.shape[1], *a.shape[2:]), t)
        new_cache = {"mlstm": flat(m_ns), "slstm": flat(s_ns)}
    aux = jnp.zeros((), jnp.float32)
    return y, aux, new_cache


def _forward_zamba(params, x, cfg, positions, mode, cache, index):
    hb = cfg.hybrid
    G = cfg.num_layers // hb.mamba_per_group
    blocks = params["blocks"]
    mamba = jax.tree.map(
        lambda a: a.reshape(G, hb.mamba_per_group, *a.shape[1:]),
        blocks["mamba"])
    shared = blocks["shared"]
    m_state = None
    if mode == "decode":
        m_state = jax.tree.map(
            lambda a: a.reshape(G, hb.mamba_per_group, *a.shape[1:]),
            cache["mamba"])

    def group(carry, xs):
        h = carry
        gi = xs[0]
        p_m = xs[1]
        st_m = xs[2] if mode == "decode" else None
        kv_g = xs[3] if mode == "decode" else None

        def m_body(hh, mxs):
            p_i = mxs[0]
            st_i = mxs[1] if mode == "decode" else None
            out, ns = ssm_mod.mamba2_block(
                p_i, hh, cfg, state=st_i, want_state=(mode == "prefill"))
            return hh + out, ns

        if mode == "train":
            m_body = jax.checkpoint(m_body)
        h, m_ns = jax.lax.scan(m_body, h,
                               (p_m, st_m) if mode == "decode" else (p_m,))
        # shared transformer block, round-robin over the distinct blocks
        sel = gi % hb.num_shared_blocks
        p_s = jax.tree.map(lambda a: a[sel], shared)

        def shared_apply(p_b, hh):
            out, _, kv = _dense_block(p_b, hh, cfg, positions, kv_g, index,
                                      mode)
            return out, kv

        if mode == "train":
            shared_apply = jax.checkpoint(
                shared_apply, policy=jax.checkpoint_policies.nothing_saveable)
        h, kv_ns = shared_apply(p_s, h)
        if mode != "decode":
            h = shard(h, "batch", "residual", None)
        return h, (m_ns, kv_ns)

    gidx = jnp.arange(G)
    xs = ((gidx, mamba) if mode != "decode"
          else (gidx, mamba, m_state, cache["shared_kv"]))
    y, (m_ns, kv_ns) = jax.lax.scan(group, x, xs)
    new_cache = None
    if mode in ("prefill", "decode") and m_ns is not None:
        flat = jax.tree.map(
            lambda a: a.reshape(a.shape[0] * a.shape[1], *a.shape[2:]), m_ns)
        new_cache = {"mamba": flat, "shared_kv": kv_ns}
    aux = jnp.zeros((), jnp.float32)
    return y, aux, new_cache


# ---------------------------------------------------------------------------
# Embedding of inputs, losses, public step functions
# ---------------------------------------------------------------------------


def embed_inputs(params: Params, batch: dict, cfg: ModelConfig) -> jax.Array:
    """[audio]/[vlm] train/prefill batches carry precomputed frontend
    embeddings ("embeds"); everything else carries token ids ("tokens")."""
    if "embeds" in batch:
        return shard(batch["embeds"].astype(ly.PDTYPE), "batch", "seq", "embed")
    return ly.embed_tokens(params["embed"], batch["tokens"])


def loss_fn(params: Params, batch: dict, cfg: ModelConfig) -> jax.Array:
    x = embed_inputs(params, batch, cfg)
    y, aux, _ = forward(params, x, cfg, mode="train")
    ce = ly.cross_entropy(params, y, batch["labels"], cfg)
    return ce + aux


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> dict:
    L = cfg.num_layers
    if cfg.family in ("dense", "audio", "vlm", "moe"):
        kv = ly.init_kv_cache(cfg, batch, max_seq, dtype)
        stack = lambda t: jnp.broadcast_to(t, (L, *t.shape))
        return {"kv": jax.tree.map(stack, kv)}
    if cfg.family == "ssm":
        xl = cfg.xlstm
        G = L // (xl.m_per_group + xl.s_per_group)
        rep = lambda t, n: jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n, *a.shape)), t)
        return {
            "mlstm": rep(ssm_mod.init_mlstm_state(cfg, batch, dtype),
                         G * xl.m_per_group),
            "slstm": rep(ssm_mod.init_slstm_state(cfg, batch),
                         G * xl.s_per_group),
        }
    if cfg.family == "hybrid":
        hb = cfg.hybrid
        G = L // hb.mamba_per_group
        rep = lambda t, n: jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n, *a.shape)), t)
        kv = ly.init_kv_cache(cfg, batch, max_seq, dtype)
        return {
            "mamba": rep(ssm_mod.init_mamba2_state(cfg, batch, dtype), L),
            "shared_kv": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (G, *a.shape)), kv),
        }
    raise ValueError(cfg.family)


def init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                     dtype=jnp.bfloat16) -> dict:
    """Paged slot cache for attention families: one shared
    (L, num_blocks, block_size, K, hd) KV block pool; slots reference
    blocks through their block tables (serve.scheduler.BlockAllocator)."""
    if cfg.family not in ("dense", "audio", "vlm", "moe"):
        raise ValueError(f"paged KV needs an attention family, "
                         f"got {cfg.family!r}")
    kv = ly.init_paged_kv_cache(cfg, num_blocks, block_size)
    stack = lambda t: jnp.broadcast_to(t, (cfg.num_layers, *t.shape))
    return {"kv": jax.tree.map(stack, kv)}


def decode_step(params: Params, cache: dict, tokens: jax.Array,
                index: jax.Array, cfg: ModelConfig,
                tables: Optional[jax.Array] = None,
                sketch: Optional[dict] = None,
                kernels: Optional[bool] = None
                ) -> Tuple[jax.Array, dict]:
    """tokens: (B, 1) int32.  Returns (logits (B, Vp) f32, new cache).
    ``tables``: optional (B, blocks_per_slot) block tables — paged-KV
    decode for attention families (dense slot cache otherwise).
    ``sketch``: optional two-span long-context state (see forward).
    ``kernels``: static paged-attention implementation switch (see
    forward)."""
    x = ly.embed_tokens(params["embed"], tokens)
    y, _, new_cache = forward(params, x, cfg, mode="decode", cache=cache,
                              index=index, tables=tables, sketch=sketch,
                              kernels=kernels)
    logits = ly.logits_fn(params, y, cfg)[:, 0]
    return logits, new_cache


def verify_step(params: Params, cache: dict, tokens: jax.Array,
                index: jax.Array, cfg: ModelConfig, tables: jax.Array,
                sketch: Optional[dict] = None,
                kernels: Optional[bool] = None
                ) -> Tuple[jax.Array, dict]:
    """Speculative-decode verification: score C tokens per slot in ONE
    compiled multi-query decode against the paged pool.

    tokens: (B, C) int32 — each slot's last committed token followed by
    its C-1 draft proposals; index: (B,) per-slot start positions (the
    slot's current decode position).  Rows for positions
    index[b] .. index[b]+C-1 scatter through the slot's block table
    exactly as C successive decode steps would, and the returned logits
    (B, C, Vp) f32 at position index[b]+i match what a plain decode step
    would produce after committing tokens[:i+1] — the property that makes
    greedy speculative decode bitwise-identical to plain greedy decode.
    Rejected rows beyond the accepted prefix are overwritten by the next
    round's writes before any query can attend them.
    """
    x = ly.embed_tokens(params["embed"], tokens)
    y, _, new_cache = forward(params, x, cfg, mode="verify", cache=cache,
                              index=index, tables=tables, sketch=sketch,
                              kernels=kernels)
    logits = ly.logits_fn(params, y, cfg)
    return logits, new_cache


def prefill(params: Params, batch: dict, cfg: ModelConfig
            ) -> Tuple[jax.Array, dict]:
    """Full-sequence prefill producing (last-token logits, cache)."""
    x = embed_inputs(params, batch, cfg)
    y, _, cache = forward(params, x, cfg, mode="prefill")
    logits = ly.logits_fn(params, y[:, -1:], cfg)[:, 0]
    return logits, cache


def prefill_chunk(params: Params, cache: dict, tokens: jax.Array,
                  table: jax.Array, start: jax.Array, cfg: ModelConfig,
                  sketch: Optional[dict] = None,
                  kernels: Optional[bool] = None) -> dict:
    """Chunked prefill step: write KV rows for absolute positions
    [start, start + C) into the paged pool through the slot's
    (blocks_per_slot,) block-table row ``table``, attending the chunk
    against everything the table already references below it (earlier
    chunks, shared prefix blocks).

    tokens: (1, C) int32 — one bucket-sized chunk of one prompt (the tail
    chunk is zero-padded; pad rows mapping past the request's reserved
    blocks are dropped by the scatter, the rest sit at positions no query
    attends before decode rewrites them).  No logits are produced: the
    scheduler resumes decode at the last prompt position, which recomputes
    that row's logits in-graph.  ``table``/``start`` are traced, so one
    compilation serves every slot and offset — the engine's prefill
    compile count is 1 regardless of prompt lengths.
    """
    x = ly.embed_tokens(params["embed"], tokens)
    _, _, new_cache = forward(params, x, cfg, mode="chunk", cache=cache,
                              index=start, tables=table, sketch=sketch,
                              kernels=kernels)
    return new_cache
