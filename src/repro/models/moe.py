"""GShard-style grouped top-k MoE with capacity, shared experts, aux loss.

Dispatch/combine are expressed as one-hot einsums (TPU-friendly: everything
lowers to MXU matmuls; no data-dependent shapes).  Tokens are routed within
fixed-size groups so the (tokens, experts, capacity) dispatch tensor stays
bounded: total elements = tokens * E * C with C ~= group * k / E * cf.

Expert placement: experts shard over the "experts" logical axis (mesh
"model") when E divides the axis size; otherwise expert weights stay
replicated and each expert's d_ff is tensor-parallel over "model"
(granite's 40 experts on a 16-way axis take this path — see DESIGN.md).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import PDTYPE, _act
from repro.models.sharding import shard


def init_moe(key: jax.Array, cfg: ModelConfig) -> dict:
    m = cfg.moe
    d, f, E = cfg.d_model, m.expert_d_ff, m.num_experts
    ks = jax.random.split(key, 5)
    sd, sf = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {
        "router": (jax.random.normal(ks[0], (d, E)) * sd).astype(jnp.float32),
        "we_gate": (jax.random.normal(ks[1], (E, d, f)) * sd).astype(PDTYPE),
        "we_up": (jax.random.normal(ks[2], (E, d, f)) * sd).astype(PDTYPE),
        "we_down": (jax.random.normal(ks[3], (E, f, d)) * sf).astype(PDTYPE),
    }
    if m.num_shared_experts:
        fs = m.num_shared_experts * f
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["ws_gate"] = (jax.random.normal(k1, (d, fs)) * sd).astype(PDTYPE)
        p["ws_up"] = (jax.random.normal(k2, (d, fs)) * sd).astype(PDTYPE)
        p["ws_down"] = (jax.random.normal(k3, (fs, d)) * sf).astype(PDTYPE)
    return p


def capacity(cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(math.ceil(m.group_size * m.top_k / m.num_experts
                      * m.capacity_factor))
    return max(4, ((c + 3) // 4) * 4)


def moe_ffn(p: dict, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss)."""
    m = cfg.moe
    B, S, d = x.shape
    E, K = m.num_experts, m.top_k
    T = B * S
    g = min(m.group_size, T)
    pad = (-T) % g
    G = (T + pad) // g
    C = capacity(cfg)

    xf = x.reshape(T, d)
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    xg = xf.reshape(G, g, d)
    xg = shard(xg, "moe_groups", None, "embed")

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, K)                     # (G, g, K)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

    onehot_e = jax.nn.one_hot(topi, E, dtype=jnp.float32)    # (G, g, K, E)
    # position of each (token, slot) within its expert queue, token-major
    flat = onehot_e.transpose(0, 2, 1, 3).reshape(G, K * g, E)
    pos_flat = jnp.cumsum(flat, axis=1) - 1.0
    pos = pos_flat.reshape(G, K, g, E).transpose(0, 2, 1, 3)  # (G, g, K, E)
    keep = (pos < C) & (onehot_e > 0)
    pos_c = jnp.where(keep, pos, 0.0).sum(axis=-1)           # (G, g, K)
    sel = keep.any(axis=-1)                                  # (G, g, K)
    onehot_c = jax.nn.one_hot(pos_c.astype(jnp.int32), C,
                              dtype=jnp.float32) * sel[..., None]

    oe = (onehot_e * keep).astype(PDTYPE)                    # (G, g, K, E)
    oc = onehot_c.astype(PDTYPE)                             # (G, g, K, C)
    dispatch = jnp.einsum("gtke,gtkc->gtec", oe, oc)         # (G, g, E, C)
    combine = jnp.einsum("gtke,gtkc,gtk->gtec", oe, oc,
                         topw.astype(PDTYPE))
    dispatch = shard(dispatch, "moe_groups", None, "experts", "expert_cap")
    combine = shard(combine, "moe_groups", None, "experts", "expert_cap")

    pd = x.dtype
    ein = jnp.einsum("gtec,gtd->gecd", dispatch, xg,
                     preferred_element_type=pd)               # (G, E, C, d)
    ein = shard(ein, "moe_groups", "experts", "expert_cap", "embed")
    hg = jnp.einsum("gecd,edf->gecf", ein, p["we_gate"],
                    preferred_element_type=pd)
    hu = jnp.einsum("gecd,edf->gecf", ein, p["we_up"],
                    preferred_element_type=pd)
    h = _act(cfg.act)(hg) * hu
    h = shard(h, "moe_groups", "experts", "expert_cap", "ff")
    eout = jnp.einsum("gecf,efd->gecd", h, p["we_down"],
                      preferred_element_type=pd)
    eout = shard(eout, "moe_groups", "experts", "expert_cap", "embed")
    y = jnp.einsum("gtec,gecd->gtd", combine, eout,
                   preferred_element_type=pd)

    if m.num_shared_experts:
        sg = jnp.einsum("gtd,df->gtf", xg, p["ws_gate"])
        su = jnp.einsum("gtd,df->gtf", xg, p["ws_up"])
        sh = _act(cfg.act)(sg) * su
        sh = shard(sh, "batch", None, "ff")
        y = y + jnp.einsum("gtf,fd->gtd", sh, p["ws_down"])

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    f_e = jnp.mean(onehot_e.sum(axis=2), axis=1)             # (G, E) token frac
    p_e = jnp.mean(probs, axis=1)                            # (G, E)
    aux = m.router_aux_weight * E * jnp.mean(jnp.sum(f_e * p_e, axis=-1))

    y = y.reshape(G * g, d)
    if pad:
        y = y[:T]
    return y.reshape(B, S, d), aux
