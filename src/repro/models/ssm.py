"""State-space / recurrent sequence mixers: Mamba-2 (SSD) and xLSTM
(mLSTM + sLSTM).

Mamba-2 follows the chunked SSD algorithm (Dao & Gu 2024, "minimal" discrete
form): quadratic attention-like compute inside chunks of ``cfg.ssm.chunk``
tokens, linear recurrence across chunks (lax.scan), per-head scalar decay.

mLSTM uses the stabilized parallel (quadratic) form for train/prefill,
chunked over query rows exactly like attention, and the constant-size
recurrent form (C: hd x hd matrix memory per head) for decode.

sLSTM is a true sequential recurrence (non-associative: tanh + normalizer
state) -> lax.scan over time; its cost is why xLSTM[7:1] uses few of them.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import PDTYPE, rms_norm, init_rms_norm
from repro.models.sharding import current_rules, shard


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv: x (B,S,C), w (cw,C), b (C)."""
    cw = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    # windows: y[t] = sum_k w[k] * x[t - (cw-1) + k]
    y = sum(xp[:, k:k + x.shape[1], :] * w[k] for k in range(cw))
    return y + b


def _conv_step(buf: jax.Array, x_t: jax.Array, w: jax.Array,
               b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single decode step of the causal conv. buf: (B, cw-1, C) past inputs,
    x_t: (B, 1, C).  Returns (new_buf, y_t)."""
    window = jnp.concatenate([buf, x_t], axis=1)           # (B, cw, C)
    y = jnp.einsum("bkc,kc->bc", window, w) + b
    return window[:, 1:], y[:, None, :]


def _conv_stash(x: jax.Array, width: int) -> jax.Array:
    """Last ``width`` inputs for the decode conv buffer, LEFT-padded with
    zeros when the sequence is shorter — the decode window is ordered
    oldest-to-newest, so a short prompt's implicit zero history must sit at
    the front, not trail the real inputs."""
    stash = x[:, -width:]
    S = stash.shape[1]
    if S < width:
        stash = jnp.pad(stash, ((0, 0), (width - S, 0), (0, 0)))
    return stash


# ===========================================================================
# Mamba-2 (SSD)
# ===========================================================================


def init_mamba2(key: jax.Array, cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    H = di // s.head_dim
    N = s.d_state
    cw = s.conv_width
    ks = jax.random.split(key, 8)
    sd = 1.0 / math.sqrt(d)
    dt = jnp.exp(jax.random.uniform(ks[6], (H,)) *
                 (math.log(0.1) - math.log(0.001)) + math.log(0.001))
    return {
        "wz": (jax.random.normal(ks[0], (d, di)) * sd).astype(PDTYPE),
        "wx": (jax.random.normal(ks[1], (d, di)) * sd).astype(PDTYPE),
        "wBC": (jax.random.normal(ks[2], (d, 2 * N)) * sd).astype(PDTYPE),
        "wdt": (jax.random.normal(ks[3], (d, H)) * sd).astype(PDTYPE),
        "conv_wx": (jax.random.normal(ks[4], (cw, di)) / math.sqrt(cw)).astype(PDTYPE),
        "conv_bx": jnp.zeros((di,), PDTYPE),
        "conv_wBC": (jax.random.normal(ks[5], (cw, 2 * N)) / math.sqrt(cw)).astype(PDTYPE),
        "conv_bBC": jnp.zeros((2 * N,), PDTYPE),
        "dt_bias": jnp.log(jnp.expm1(dt)).astype(jnp.float32),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "norm": init_rms_norm(di),
        "out_proj": (jax.random.normal(ks[7], (di, d)) / math.sqrt(di)).astype(PDTYPE),
        "pre_norm": init_rms_norm(d),
    }


def _ssd_chunked(X, dtA, dt, Bm, Cm, cs, init_state=None):
    """Chunked SSD scan.
    X: (B,S,H,P) values; dtA: (B,S,H) = dt*A (negative); dt: (B,S,H);
    Bm, Cm: (B,S,N).  Returns (Y (B,S,H,P), final_state (B,H,N,P))."""
    B_, S, H, P = X.shape
    N = Bm.shape[-1]
    pad = (-S) % cs
    if pad:  # zero-pad the tail: dt=0 there, so padded steps are identity
        X = jnp.pad(X, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dtA = jnp.pad(dtA, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // cs
    Xt = (X * dt[..., None]).reshape(B_, nc, cs, H, P).astype(PDTYPE)
    Ac = dtA.reshape(B_, nc, cs, H).astype(jnp.float32)
    Bc = Bm.reshape(B_, nc, cs, N).astype(PDTYPE)
    Cc = Cm.reshape(B_, nc, cs, N).astype(PDTYPE)
    cum = jnp.cumsum(Ac, axis=2)                              # (B,nc,cs,H)

    # intra-chunk (quadratic within chunk)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)            # (B,nc,cs,cs)
    ldiff = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # (B,nc,i,j,H)
    ii, jj = jnp.arange(cs)[:, None], jnp.arange(cs)[None, :]
    mask = (ii >= jj)[None, None, :, :, None]
    L = jnp.where(mask, jnp.exp(ldiff), 0.0).astype(PDTYPE)
    L = shard(L, "batch", "chunks", None, None, "ssm_heads")
    Yd = jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, L, Xt,
                    preferred_element_type=PDTYPE)

    # chunk states
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum).astype(PDTYPE)  # (B,nc,cs,H)
    S_chunk = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", Bc, decay_to_end, Xt,
                         preferred_element_type=PDTYPE)
    S_chunk = shard(S_chunk, "batch", "chunks", "ssm_heads", None, None)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                   # (B,nc,H)

    # inter-chunk recurrence: state_c = state_{c-1} * decay_c + S_c.
    # Two-level associative scan: a local scan within each context shard
    # (chunk axis stays sharded, no gathers) + a tiny cross-shard scan of
    # per-shard boundary states.  Falls back to one flat scan when the
    # chunk axis isn't context-sharded.
    dec_f = chunk_decay.astype(jnp.float32)                   # (B,nc,H)
    s_f = S_chunk.astype(jnp.float32)                         # (B,nc,H,N,P)
    if init_state is not None:
        s_f = s_f.at[:, 0].add(init_state * dec_f[:, 0, :, None, None])
    states_incl = _two_level_state_scan(dec_f, s_f)
    final = states_incl[:, -1]                                # (B,H,N,P)
    # state BEFORE chunk c = inclusive state of chunk c-1 (zero for c=0)
    states_in = jnp.pad(states_incl[:, :-1],
                        ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    if init_state is not None:
        states_in = states_in.at[:, 0].add(init_state)

    Yi = jnp.einsum("bcin,bcih,bchnp->bcihp", Cc,
                    jnp.exp(cum).astype(PDTYPE),
                    states_in.astype(PDTYPE),
                    preferred_element_type=PDTYPE)
    Y = (Yd + Yi).reshape(B_, Sp, H, P)[:, :S]
    return Y, final


def _two_level_state_scan(dec: jax.Array, st: jax.Array) -> jax.Array:
    """Inclusive scan of state_c = state_{c-1} * dec_c + st_c over axis 1.
    dec: (B,nc,H); st: (B,nc,H,N,P)."""
    def combine(a, b):
        da, sa = a
        db, sb = b
        return (da * db, sa * db[..., None, None] + sb)

    rules = current_rules() or {}
    ns = rules.get("ctx_shards", 1)
    B_, nc = st.shape[:2]
    if ns <= 1 or nc % ns or nc == ns:
        _, out = jax.lax.associative_scan(combine, (dec, st), axis=1)
        return out
    ncl = nc // ns
    d2 = dec.reshape(B_, ns, ncl, *dec.shape[2:])
    s2 = st.reshape(B_, ns, ncl, *st.shape[2:])
    dloc, sloc = jax.lax.associative_scan(combine, (d2, s2), axis=2)
    # cross-shard exclusive prefix of per-shard totals (small tensors)
    dt, stt = dloc[:, :, -1], sloc[:, :, -1]
    dp, sp = jax.lax.associative_scan(combine, (dt, stt), axis=1)
    sp_ex = jnp.pad(sp[:, :-1], ((0, 0), (1, 0)) + ((0, 0),) * (sp.ndim - 2))
    # fold the shard prefix into every local chunk
    out = sp_ex[:, :, None] * dloc[..., None, None] + sloc
    return out.reshape(B_, nc, *st.shape[2:])


def mamba2_block(p: dict, x: jax.Array, cfg: ModelConfig,
                 state: Optional[dict] = None, want_state: bool = False
                 ) -> Tuple[jax.Array, Optional[dict]]:
    """x: (B,S,d).  If ``state`` is given (decode), S must be 1 and the
    returned state is updated; otherwise runs the chunked train/prefill path.
    state = {"ssm": (B,H,N,P) f32, "conv_x": (B,cw-1,di), "conv_BC": (B,cw-1,2N)}
    """
    s = cfg.ssm
    B, S, d = x.shape
    x = rms_norm(x, p["pre_norm"], cfg.norm_eps)
    di = s.expand * d
    H = di // s.head_dim
    P = s.head_dim
    N = s.d_state

    z = jnp.einsum("bsd,de->bse", x, p["wz"])
    xr = jnp.einsum("bsd,de->bse", x, p["wx"])
    BC = jnp.einsum("bsd,dn->bsn", x, p["wBC"])
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["wdt"]).astype(jnp.float32)
    z = shard(z, "batch", "seq", "ssm_inner")
    xr = shard(xr, "batch", "seq", "ssm_inner")

    new_state = None
    if state is None:
        if want_state:  # stash conv inputs for the decode conv buffer
            cbx = _conv_stash(xr, s.conv_width - 1)
            cbc = _conv_stash(BC, s.conv_width - 1)
        xr = jax.nn.silu(_causal_conv(xr, p["conv_wx"], p["conv_bx"]))
        BC = jax.nn.silu(_causal_conv(BC, p["conv_wBC"], p["conv_bBC"]))
    else:
        cbx, xr_t = _conv_step(state["conv_x"], xr, p["conv_wx"], p["conv_bx"])
        cbc, BC_t = _conv_step(state["conv_BC"], BC, p["conv_wBC"], p["conv_bBC"])
        xr, BC = jax.nn.silu(xr_t), jax.nn.silu(BC_t)

    Bm, Cm = BC[..., :N], BC[..., N:]
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])               # (B,S,H)
    A = -jnp.exp(p["A_log"])                                  # (H,)
    X = xr.reshape(B, S, H, P)
    X = shard(X, "batch", "seq", "ssm_heads", None)

    if state is None:
        Y, final = _ssd_chunked(X, dt * A, dt, Bm, Cm, min(s.chunk, S))
        if want_state:
            new_state = {"ssm": final, "conv_x": cbx, "conv_BC": cbc}
    else:
        # single-step recurrence
        ssm = state["ssm"]                                    # (B,H,N,P)
        dA = jnp.exp(dt[:, 0] * A)                            # (B,H)
        dBx = jnp.einsum("bn,bh,bhp->bhnp", Bm[:, 0], dt[:, 0], X[:, 0])
        ssm = ssm * dA[:, :, None, None] + dBx.astype(jnp.float32)
        Y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0], ssm.astype(Cm.dtype))[:, None]
        new_state = {"ssm": ssm, "conv_x": cbx, "conv_BC": cbc}

    Y = Y + X * p["D"][:, None].astype(X.dtype)
    y = Y.reshape(B, S, di)
    y = rms_norm((y * jax.nn.silu(z)).astype(x.dtype), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, new_state


def init_mamba2_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> dict:
    s = cfg.ssm
    di = s.expand * cfg.d_model
    H = di // s.head_dim
    return {
        "ssm": jnp.zeros((batch, H, s.d_state, s.head_dim), jnp.float32),
        "conv_x": jnp.zeros((batch, s.conv_width - 1, di), dtype),
        "conv_BC": jnp.zeros((batch, s.conv_width - 1, 2 * s.d_state), dtype),
    }


# ===========================================================================
# xLSTM: mLSTM
# ===========================================================================


def init_mlstm(key: jax.Array, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    dm = int(cfg.xlstm.proj_factor_m * d)
    H = cfg.num_heads
    cw = 4
    ks = jax.random.split(key, 8)
    sd, sm = 1.0 / math.sqrt(d), 1.0 / math.sqrt(dm)
    return {
        "wxb": (jax.random.normal(ks[0], (d, dm)) * sd).astype(PDTYPE),
        "wzb": (jax.random.normal(ks[1], (d, dm)) * sd).astype(PDTYPE),
        "conv_w": (jax.random.normal(ks[2], (cw, dm)) / math.sqrt(cw)).astype(PDTYPE),
        "conv_b": jnp.zeros((dm,), PDTYPE),
        "wq": (jax.random.normal(ks[3], (dm, dm)) * sm).astype(PDTYPE),
        "wk": (jax.random.normal(ks[4], (dm, dm)) * sm).astype(PDTYPE),
        "wv": (jax.random.normal(ks[5], (dm, dm)) * sm).astype(PDTYPE),
        "wi": (jax.random.normal(ks[6], (dm, H)) * sm).astype(jnp.float32),
        "wf": jnp.zeros((dm, H), jnp.float32),
        "bi": jnp.zeros((H,), jnp.float32),
        "bf": jnp.full((H,), 3.0, jnp.float32),   # open forget gates at init
        "norm": init_rms_norm(dm),
        "out_proj": (jax.random.normal(ks[7], (dm, d)) * sm).astype(PDTYPE),
        "pre_norm": init_rms_norm(d),
    }


def mlstm_block(p: dict, x: jax.Array, cfg: ModelConfig,
                state: Optional[dict] = None, q_chunk: int = 512,
                want_state: bool = False) -> Tuple[jax.Array, Optional[dict]]:
    B, S, d = x.shape
    x = rms_norm(x, p["pre_norm"], cfg.norm_eps)
    dm = int(cfg.xlstm.proj_factor_m * d)
    H = cfg.num_heads
    hd = dm // H

    xb = jnp.einsum("bsd,de->bse", x, p["wxb"])
    zb = jnp.einsum("bsd,de->bse", x, p["wzb"])
    xb = shard(xb, "batch", "seq", "ssm_inner")

    new_state = None
    if state is None:
        xc = jax.nn.silu(_causal_conv(xb, p["conv_w"], p["conv_b"]))
    else:
        cb, xc_t = _conv_step(state["conv"], xb, p["conv_w"], p["conv_b"])
        xc = jax.nn.silu(xc_t)

    q = jnp.einsum("bse,ef->bsf", xc, p["wq"]).reshape(B, S, H, hd)
    k = jnp.einsum("bse,ef->bsf", xc, p["wk"]).reshape(B, S, H, hd) / math.sqrt(hd)
    v = jnp.einsum("bse,ef->bsf", xb, p["wv"]).reshape(B, S, H, hd)
    i_pre = (jnp.einsum("bse,eh->bsh", xc.astype(jnp.float32), p["wi"])
             + p["bi"])                                        # (B,S,H)
    f_pre = (jnp.einsum("bse,eh->bsh", xc.astype(jnp.float32), p["wf"])
             + p["bf"])
    logf = -jax.nn.softplus(-f_pre)                            # log sigmoid

    if state is None:
        h = _mlstm_parallel(q, k, v, i_pre, logf, min(q_chunk, S))
        if want_state:
            b = jnp.cumsum(logf, axis=1)                       # (B,S,H)
            dexp = b[:, -1:, :] - b + i_pre                    # (B,S,H)
            m_fin = jnp.max(dexp, axis=1)                      # (B,H)
            w = jnp.exp(dexp - m_fin[:, None, :])              # (B,S,H)
            kf = k.astype(jnp.float32)
            vf = v.astype(jnp.float32)
            C_fin = jnp.einsum("bsh,bshp,bshq->bhpq", w, kf, vf)
            n_fin = jnp.einsum("bsh,bshp->bhp", w, kf)
            new_state = {"C": C_fin, "n": n_fin, "m": m_fin,
                         "conv": _conv_stash(xb, 3).astype(xb.dtype)}
    else:
        C, n, m = state["C"], state["n"], state["m"]           # f32
        i_t, lf_t = i_pre[:, 0], logf[:, 0]                    # (B,H)
        m_new = jnp.maximum(lf_t + m, i_t)
        fd = jnp.exp(lf_t + m - m_new)[..., None]
        idg = jnp.exp(i_t - m_new)[..., None]
        kf = k[:, 0].astype(jnp.float32)
        vf = v[:, 0].astype(jnp.float32)
        C = C * fd[..., None] + idg[..., None] * kf[..., :, None] * vf[..., None, :]
        n = n * fd + idg * kf
        qf = q[:, 0].astype(jnp.float32)
        num = jnp.einsum("bhp,bhpq->bhq", qf, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", qf, n)),
                          jnp.exp(-m_new))
        h = (num / den[..., None])[:, None].astype(x.dtype)    # (B,1,H,hd)
        new_state = {"C": C, "n": n, "m": m_new, "conv": cb}

    h = h.reshape(B, S, dm)
    h = rms_norm(h, p["norm"], cfg.norm_eps) * jax.nn.silu(zb)
    out = jnp.einsum("bse,ed->bsd", h, p["out_proj"])
    return out, new_state


def _mlstm_parallel(q, k, v, i_pre, logf, qc):
    """Stabilized parallel mLSTM, chunked over query rows.
    q,k,v: (B,S,H,hd); i_pre, logf: (B,S,H)."""
    B, S, H, hd = q.shape
    b = jnp.cumsum(logf, axis=1)                               # (B,S,H) f32
    pad = (-S) % qc
    qp, bp = q, b
    if pad:
        qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bp = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nq = Sp // qc
    qg = qp.reshape(B, nq, qc, H, hd).transpose(1, 0, 2, 3, 4)
    bg = bp.reshape(B, nq, qc, H).transpose(1, 0, 2, 3)
    k_idx = jnp.arange(S)

    @jax.checkpoint
    def chunk(carry, inp):
        ci, qi, bi = inp                                       # qi (B,qc,H,hd)
        q_pos = ci * qc + jnp.arange(qc)
        causal = q_pos[:, None] >= k_idx[None, :]              # (qc,S)
        Dm = bi[:, :, None, :] - b[:, None, :, :] + i_pre[:, None, :, :]
        Dm = jnp.where(causal[None, :, :, None], Dm, -jnp.inf) # (B,qc,S,H)
        m = jnp.max(Dm, axis=2)                                # (B,qc,H)
        Dp = jnp.exp(Dm - m[:, :, None, :]).astype(q.dtype)
        s = jnp.einsum("bqhp,bshp->bqsh", qi, k)
        w = s * Dp
        num = jnp.einsum("bqsh,bshp->bqhp", w, v)
        den = jnp.maximum(
            jnp.abs(jnp.sum(w.astype(jnp.float32), axis=2)),
            jnp.exp(-m))                                       # (B,qc,H)
        return carry, (num / den[..., None].astype(num.dtype))

    _, hg = jax.lax.scan(chunk, None, (jnp.arange(nq), qg, bg))
    return hg.transpose(1, 0, 2, 3, 4).reshape(B, Sp, H, hd)[:, :S]


def init_mlstm_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> dict:
    d = cfg.d_model
    dm = int(cfg.xlstm.proj_factor_m * d)
    H = cfg.num_heads
    hd = dm // H
    return {
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.zeros((batch, H), jnp.float32),
        "conv": jnp.zeros((batch, 3, dm), dtype),
    }


# ===========================================================================
# xLSTM: sLSTM
# ===========================================================================


def _slstm_up_dim(cfg: ModelConfig) -> int:
    """4/3 * d rounded to a 128 multiple (TPU lane / 16-way TP alignment)."""
    return max(128, int(round(cfg.xlstm.proj_factor_s * cfg.d_model / 128)) * 128)


def init_slstm(key: jax.Array, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    H = cfg.num_heads
    hd = d // H
    ds = _slstm_up_dim(cfg)
    ks = jax.random.split(key, 12)
    sd, sh = 1.0 / math.sqrt(d), 1.0 / math.sqrt(hd)
    p = {}
    for gi, g in enumerate(("i", "f", "z", "o")):
        p[f"w{g}"] = (jax.random.normal(ks[gi], (d, d)) * sd).astype(PDTYPE)
        p[f"r{g}"] = (jax.random.normal(ks[4 + gi], (H, hd, hd)) * sh).astype(PDTYPE)
        p[f"b{g}"] = (jnp.full((d,), 1.0, jnp.float32) if g == "f"
                      else jnp.zeros((d,), jnp.float32))
    p["norm"] = init_rms_norm(d)
    p["pre_norm"] = init_rms_norm(d)
    p["w_up_g"] = (jax.random.normal(ks[8], (d, ds)) * sd).astype(PDTYPE)
    p["w_up"] = (jax.random.normal(ks[9], (d, ds)) * sd).astype(PDTYPE)
    p["w_down"] = (jax.random.normal(ks[10], (ds, d)) / math.sqrt(ds)).astype(PDTYPE)
    return p


def _slstm_cell(rb, carry, pre):
    """One timestep. carry: (c, n, h, m) each (B,H,hd) f32; pre: dict of
    per-gate input preactivations at t, each (B,H,hd) f32.  ``rb`` holds
    the recurrent matrices pre-broadcast to (B,H,hd,hd): the batch dim
    keeps the backward dR accumulation batch-LOCAL through the scan (one
    cross-batch reduce at the end instead of one per timestep)."""
    c, n, h, m = carry
    rec = {g: jnp.einsum("bhp,bhpq->bhq", h.astype(PDTYPE), rb[g]
                         ).astype(jnp.float32) for g in ("i", "f", "z", "o")}
    it = pre["i"] + rec["i"]
    ft = pre["f"] + rec["f"]
    zt = jnp.tanh(pre["z"] + rec["z"])
    ot = jax.nn.sigmoid(pre["o"] + rec["o"])
    logf = -jax.nn.softplus(-ft)
    m_new = jnp.maximum(logf + m, it)
    fp = jnp.exp(logf + m - m_new)
    ip = jnp.exp(it - m_new)
    c = fp * c + ip * zt
    n = fp * n + ip
    h = ot * c / jnp.maximum(n, 1.0)
    return (c, n, h, m_new)


def slstm_block(p: dict, x: jax.Array, cfg: ModelConfig,
                state: Optional[dict] = None, want_state: bool = False
                ) -> Tuple[jax.Array, Optional[dict]]:
    B, S, d = x.shape
    x = rms_norm(x, p["pre_norm"], cfg.norm_eps)
    H = cfg.num_heads
    hd = d // H
    pre = {g: (jnp.einsum("bsd,de->bse", x, p[f"w{g}"]).astype(jnp.float32)
               + p[f"b{g}"]).reshape(B, S, H, hd)
           for g in ("i", "f", "z", "o")}

    if state is None:
        z = jnp.zeros((B, H, hd), jnp.float32)
        carry0 = (z, z, z, z)
    else:
        carry0 = (state["c"], state["n"], state["h"], state["m"])

    rb = {g: jnp.broadcast_to(p[f"r{g}"], (B,) + p[f"r{g}"].shape)
          for g in ("i", "f", "z", "o")}

    def step(carry, pre_t):
        carry = _slstm_cell(rb, carry, pre_t)
        return carry, carry[2]                                 # emit h

    pre_t = jax.tree.map(lambda a: a.transpose(1, 0, 2, 3), pre)  # (S,B,H,hd)
    carry, hs = jax.lax.scan(step, carry0, pre_t)
    y = hs.transpose(1, 0, 2, 3).reshape(B, S, d).astype(x.dtype)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    # post up/down GLU (proj factor 4/3)
    g = jnp.einsum("bsd,df->bsf", y, p["w_up_g"])
    u = jnp.einsum("bsd,df->bsf", y, p["w_up"])
    out = jnp.einsum("bsf,fd->bsd", jax.nn.gelu(g) * u, p["w_down"])
    new_state = None
    if state is not None or want_state:
        c, n, h, m = carry
        new_state = {"c": c, "n": n, "h": h, "m": m}
    return out, new_state


def init_slstm_state(cfg: ModelConfig, batch: int) -> dict:
    H = cfg.num_heads
    hd = cfg.d_model // H
    z = jnp.zeros((batch, H, hd), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z}
