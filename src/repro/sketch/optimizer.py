"""Sketched optimizers: AdamW / Adagrad with (m, v) moments in CSVec tables.

After parameters, AdamW's f32 (m, v) is the largest memory consumer of
training (8 bytes/param).  Both moment recursions are linear-ish in the
per-step statistic, and count-sketch tables are linear containers, so the
EMAs can run IN SKETCH SPACE exactly:

    m_t table = b1 * table + (1-b1) * CS(g_t)
    => query(m_t) is the count-sketch estimate of the true dense m_t.

First moments use a signed count sketch (median-of-rows, unbiased); second
moments use count-min (unsigned, min-of-rows): v feeds a denominator, and
count-min's one-sided overestimate can only shrink the step — the safe
failure mode (cf. GeKeShi/Count-Sketch-Optimizers).  Hashes are FIXED per
leaf (fresh hashes would decohere the EMA), evaluated on the fly from
O(1) coefficients.

Every leaf with >= min_elems elements gets sketched moments with
rows * cols ~= numel / ratio table entries per moment (a ~ratio x state
reduction); small leaves (norms, biases) stay dense — they are cheap and
stability-critical.  The per-leaf hot path is the fused update-retrieve
op (kernels/sketch_update.py compiled on TPU; its jnp oracle elsewhere).

State is a plain pytree (step + per-param-leaf DenseMoments |
SketchedMoments), so checkpointing (train/checkpoint.py), the loss-spike
skip guard in train/loop.py, and sharding (launch/shardings.py:
opt_state_pspecs) all treat it like any other optimizer state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.sketch.csvec import CSVec, csvec_zeros, state_bytes

DEFAULT_MIN_ELEMS = 1 << 16


class DenseMoments(NamedTuple):
    m: jax.Array
    v: jax.Array


class SketchedMoments(NamedTuple):
    m: CSVec                  # signed count sketch
    v: CSVec                  # unsigned count-min


class SketchedAdamWState(NamedTuple):
    step: jax.Array           # () int32
    moments: Any              # params-shaped tree of *Moments leaves


class SketchedAdagradState(NamedTuple):
    step: jax.Array
    moments: Any              # params-shaped tree of CSVec | jax.Array (v)


def _is_moments(x) -> bool:
    return isinstance(x, (DenseMoments, SketchedMoments, CSVec))


def _cols_for(numel: int, ratio: int, rows: int) -> int:
    """Per-row table width: numel/(rows*ratio) rounded up to lane-aligned
    multiples (128; 256 when large, so FSDP can shard 256-way)."""
    c = -(-numel // (rows * ratio))
    align = 256 if c >= 2048 else 128
    return -(-c // align) * align


def _leaf_seed(seed: int, i: int) -> int:
    return (int(seed) * 1_000_003 + i) % (1 << 31)


def sketched_adamw_init(params: Any, ratio: int, rows: int = 3,
                        min_elems: int = DEFAULT_MIN_ELEMS,
                        seed: int = 0) -> SketchedAdamWState:
    leaves, tdef = jax.tree.flatten(params)
    moments = []
    for i, p in enumerate(leaves):
        if ratio > 0 and p.size >= min_elems:
            cols = _cols_for(p.size, ratio, rows)
            moments.append(SketchedMoments(
                m=csvec_zeros(p.size, cols, rows,
                              seed=_leaf_seed(seed, 2 * i), signed=True),
                v=csvec_zeros(p.size, cols, rows,
                              seed=_leaf_seed(seed, 2 * i + 1),
                              signed=False)))
        else:
            z = jnp.zeros(p.shape, jnp.float32)
            moments.append(DenseMoments(m=z, v=z))
    return SketchedAdamWState(step=jnp.zeros((), jnp.int32),
                              moments=jax.tree.unflatten(tdef, moments))


def sketched_adamw_update(grads: Any, state: SketchedAdamWState, params: Any,
                          lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
                          eps: float = 1e-8, weight_decay: float = 0.01,
                          use_pallas: bool | None = None,
                          ) -> Tuple[Any, SketchedAdamWState]:
    from repro.kernels.ops import sketch_update_op
    from repro.train.optimizer import adamw_leaf_update

    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd_dense(p, g, mom: DenseMoments):
        newp, m, v = adamw_leaf_update(p, g, mom.m, mom.v, lr=lr, b1=b1,
                                       b2=b2, eps=eps,
                                       weight_decay=weight_decay,
                                       bc1=bc1, bc2=bc2)
        return newp, DenseMoments(m=m, v=v)

    def upd_sketched(p, g, mom: SketchedMoments):
        gf = g.reshape(-1).astype(jnp.float32)
        new_m, new_v, m_hat, v_hat = sketch_update_op(
            gf, mom.m.table, mom.v.table, mom.m.coeffs, mom.v.coeffs,
            b1=b1, b2=b2, use_pallas=use_pallas)
        mh = (m_hat / bc1).reshape(p.shape)
        vh = (jnp.maximum(v_hat, 0.0) / bc2).reshape(p.shape)
        delta = mh / (jnp.sqrt(vh) + eps) \
            + weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                SketchedMoments(m=dataclasses.replace(mom.m, table=new_m),
                                v=dataclasses.replace(mom.v, table=new_v)))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mom = jax.tree.leaves(state.moments, is_leaf=_is_moments)
    out = [upd_sketched(p, g, mo) if isinstance(mo, SketchedMoments)
           else upd_dense(p, g, mo)
           for p, g, mo in zip(flat_p, flat_g, flat_mom)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_moments = jax.tree.unflatten(tdef, [o[1] for o in out])
    return new_params, SketchedAdamWState(step=step, moments=new_moments)


# ---------------------------------------------------------------------------
# Adagrad variant (second moment only, accumulated — not an EMA)
# ---------------------------------------------------------------------------


def sketched_adagrad_init(params: Any, ratio: int, rows: int = 3,
                          min_elems: int = DEFAULT_MIN_ELEMS,
                          seed: int = 0) -> SketchedAdagradState:
    leaves, tdef = jax.tree.flatten(params)
    moments = []
    for i, p in enumerate(leaves):
        if ratio > 0 and p.size >= min_elems:
            cols = _cols_for(p.size, ratio, rows)
            moments.append(csvec_zeros(p.size, cols, rows,
                                       seed=_leaf_seed(seed, i),
                                       signed=False))
        else:
            moments.append(jnp.zeros(p.shape, jnp.float32))
    return SketchedAdagradState(step=jnp.zeros((), jnp.int32),
                                moments=jax.tree.unflatten(tdef, moments))


def sketched_adagrad_update(grads: Any, state: SketchedAdagradState,
                            params: Any, lr: float = 1e-2, eps: float = 1e-8,
                            ) -> Tuple[Any, SketchedAdagradState]:
    from repro.sketch.csvec import accumulate, query_all

    def upd(p, g, mom):
        gf = g.astype(jnp.float32)
        if isinstance(mom, CSVec):
            mom = accumulate(mom, jnp.square(gf))
            vh = jnp.maximum(query_all(mom), 0.0).reshape(p.shape)
        else:
            mom = mom + jnp.square(gf)
            vh = mom
        newp = (p.astype(jnp.float32)
                - lr * gf / (jnp.sqrt(vh) + eps)).astype(p.dtype)
        return newp, mom

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mom = jax.tree.leaves(state.moments, is_leaf=_is_moments)
    out = [upd(p, g, mo) for p, g, mo in zip(flat_p, flat_g, flat_mom)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            SketchedAdagradState(
                step=state.step + 1,
                moments=jax.tree.unflatten(tdef, [o[1] for o in out])))


# ---------------------------------------------------------------------------
# Accounting
# ---------------------------------------------------------------------------


def moment_state_bytes(state) -> dict:
    """Persistent moment-state bytes, split dense vs sketched, plus the
    bytes the sketched leaves would have cost dense (f32 m+v or v)."""
    dense = sketched = dense_equiv = 0
    per_moment = 2 if isinstance(state, SketchedAdamWState) else 1
    for mo in jax.tree.leaves(state.moments, is_leaf=_is_moments):
        if isinstance(mo, SketchedMoments):
            sketched += state_bytes(mo.m) + state_bytes(mo.v)
            dense_equiv += 2 * mo.m.d * 4
        elif isinstance(mo, CSVec):
            sketched += state_bytes(mo)
            dense_equiv += mo.d * 4
        elif isinstance(mo, DenseMoments):
            dense += mo.m.size * 4 + mo.v.size * 4
        else:
            dense += mo.size * 4 * per_moment
    return {"dense": dense, "sketched": sketched,
            "sketched_dense_equiv": dense_equiv,
            "total": dense + sketched}
