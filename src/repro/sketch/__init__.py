"""Sketched-state subsystem: accumulable count-sketch containers and
optimizers whose moment state lives in O(numel/ratio) sketch tables.

Layering:
  hashing.py   — in-graph uint32 hash families (shared with the Pallas
                 kernel in repro.kernels.sketch_update)
  csvec.py     — functional CSVec pytree container (accumulate / query /
                 median-of-rows / topk heavy hitters)
  optimizer.py — sketched AdamW / Adagrad over CSVec moment tables
"""
from repro.sketch.csvec import (CSVec, accumulate, accumulate_coords,
                                csvec_zeros, decay, l2_estimate, merge,
                                query, query_all, query_row, state_bytes,
                                topk)
from repro.sketch.optimizer import (DenseMoments, SketchedAdamWState,
                                    SketchedMoments, moment_state_bytes,
                                    sketched_adagrad_init,
                                    sketched_adagrad_update,
                                    sketched_adamw_init,
                                    sketched_adamw_update)

__all__ = [
    "CSVec", "accumulate", "accumulate_coords", "csvec_zeros", "decay",
    "l2_estimate", "merge", "query", "query_all", "query_row",
    "state_bytes", "topk",
    "DenseMoments", "SketchedMoments", "SketchedAdamWState",
    "moment_state_bytes", "sketched_adagrad_init", "sketched_adagrad_update",
    "sketched_adamw_init", "sketched_adamw_update",
]
