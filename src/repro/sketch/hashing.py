"""In-graph uint32 hash family for sketch containers.

Unlike ``core.hashes`` (tabulated affine-mod-Mersenne pairs, O(I) storage
per mode), the optimizer-state sketches need O(1)-storage hashes evaluated
on the fly: a tabulated (rows, numel) bucket/sign pair would cost 8 bytes
per element per row and erase the whole memory win of sketching (m, v).

The family here is multiply-add then MurmurHash3 finalize, entirely in
uint32 with mod-2^32 wraparound, so the SAME arithmetic runs in plain jnp,
in Pallas interpret mode, and compiled on the TPU VPU.  The finalizer is a
bijection on uint32, so composing it with the multiply-add stage preserves
the (approximate) 2-universality of multiply-shift hashing; empirical
bucket/sign uniformity is asserted in tests/test_sketch_opt.py.

Coefficients are drawn host-side in numpy from a seed (one (rows, 4)
uint32 array per CSVec — bucket a/b, sign a/b) and cached per
(seed, rows): the tables they generate are never stored.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

_M1 = 0x85EBCA6B
_M2 = 0xC2B2AE35


def make_coeffs(seed: int, rows: int) -> jax.Array:
    """(rows, 4) uint32: (a_bucket, b_bucket, a_sign, b_sign); a's odd."""
    rng = np.random.RandomState(np.uint32(seed) ^ 0x5EEDC0DE)
    c = rng.randint(0, 2 ** 31, size=(rows, 4)).astype(np.uint64)
    c = (c * 2 + 1) % (2 ** 32)          # odd multipliers (and odd b: fine)
    return jnp.asarray(c.astype(np.uint32))


def mix32(x: jax.Array) -> jax.Array:
    """MurmurHash3 fmix32 (a bijection on uint32)."""
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(_M1)
    x = x ^ (x >> jnp.uint32(13))
    x = x * jnp.uint32(_M2)
    x = x ^ (x >> jnp.uint32(16))
    return x


def bucket_hash(idx: jax.Array, a: jax.Array, b: jax.Array,
                c: int) -> jax.Array:
    """Buckets in [0, c) for (possibly broadcast) uint32 indices."""
    return (mix32(a * idx + b) % jnp.uint32(c)).astype(jnp.int32)


def sign_hash(idx: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """Signs in {-1.0, +1.0} (f32) from the top mixed bit."""
    bit = (mix32(a * idx + b) >> jnp.uint32(31)).astype(jnp.float32)
    return 1.0 - 2.0 * bit


def row_buckets_signs(coeffs: jax.Array, idx: jax.Array, c: int,
                      signed: bool):
    """(rows, n) buckets and signs for an int index vector.

    ``signed=False`` (count-min mode) returns all-ones signs.
    """
    u = idx.astype(jnp.uint32)[None, :]
    bk = bucket_hash(u, coeffs[:, 0:1], coeffs[:, 1:2], c)
    if signed:
        sg = sign_hash(u, coeffs[:, 2:3], coeffs[:, 3:4])
    else:
        sg = jnp.ones(bk.shape, jnp.float32)
    return bk, sg


@functools.lru_cache(maxsize=None)
def _cached_coeffs_key(seed: int, rows: int):
    # lru_cache must hold host arrays, not traced values
    return np.asarray(make_coeffs(seed, rows))


def cached_coeffs(seed: int, rows: int) -> jax.Array:
    """Coefficients for (seed, rows), cached host-side."""
    return jnp.asarray(_cached_coeffs_key(int(seed), int(rows)))
