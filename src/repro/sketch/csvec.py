"""Functional CSVec: a count-sketch vector container as a JAX pytree.

The container holds ONLY the (rows, cols) table plus a (rows, 4) uint32
hash-coefficient array — O(rows * cols) state for a d-dimensional vector,
with bucket/sign hashes recomputed on the fly (see sketch/hashing.py).
It is linear and mergeable, so it serves as

  * optimizer moment state (sketched AdamW/Adagrad in sketch/optimizer.py),
  * a streaming gradient accumulator (tables of microbatch grads add),
  * a serve-side frequency/heavy-hitter cache (count-min mode).

Two estimate modes, chosen at construction:
  signed=True  — classic count sketch: signed accumulate, median-of-rows
                 estimate (unbiased; Charikar et al. 2002).
  signed=False — count-min: unsigned accumulate, min-of-rows estimate
                 (one-sided overestimate for nonnegative streams; the safe
                 choice for second moments, cf. Count-Sketch-Optimizers).

Everything is functional: ``accumulate`` and friends return a new CSVec.
Shape/metadata (d, signed) ride in pytree aux data, so CSVec instances
flow through jit / tree.map / checkpoints unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.sketch.hashing import cached_coeffs, row_buckets_signs


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CSVec:
    table: jax.Array          # (rows, cols) f32
    coeffs: jax.Array         # (rows, 4) uint32 hash coefficients
    d: int                    # dimensionality of the sketched vector (aux)
    signed: bool              # count-sketch (True) vs count-min (False)
    seed: int = 0             # hash seed (aux; lets merge() check hashes
                              # statically, even under jit tracing)

    # -- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        return (self.table, self.coeffs), (self.d, self.signed, self.seed)

    @classmethod
    def tree_unflatten(cls, aux, children):
        table, coeffs = children
        return cls(table=table, coeffs=coeffs, d=aux[0], signed=aux[1],
                   seed=aux[2])

    # -- convenience -----------------------------------------------------
    @property
    def rows(self) -> int:
        return self.table.shape[0]

    @property
    def cols(self) -> int:
        return self.table.shape[1]


def csvec_zeros(d: int, cols: int, rows: int = 3, seed: int = 0,
                signed: bool = True) -> CSVec:
    """Empty sketch for a d-vector: (rows, cols) zeros + cached coeffs."""
    return CSVec(table=jnp.zeros((rows, cols), jnp.float32),
                 coeffs=cached_coeffs(seed, rows), d=int(d), signed=signed,
                 seed=int(seed))


# ---------------------------------------------------------------------------
# Accumulate
# ---------------------------------------------------------------------------


def accumulate(sk: CSVec, vec: jax.Array) -> CSVec:
    """sk + CS(vec): scatter-add every coordinate of a dense d-vector."""
    flat = vec.reshape(-1).astype(jnp.float32)
    return accumulate_coords(sk, jnp.arange(flat.shape[0], dtype=jnp.int32),
                             flat)


def accumulate_coords(sk: CSVec, idx: jax.Array, vals: jax.Array) -> CSVec:
    """Sparse accumulate: add vals[j] at coordinates idx[j]."""
    bk, sg = row_buckets_signs(sk.coeffs, idx, sk.cols, sk.signed)
    rows_ix = jnp.broadcast_to(
        jnp.arange(sk.rows, dtype=jnp.int32)[:, None], bk.shape)
    upd = sg * vals.astype(jnp.float32)[None, :]
    table = sk.table.at[rows_ix.reshape(-1), bk.reshape(-1)].add(
        upd.reshape(-1))
    return dataclasses.replace(sk, table=table)


# ---------------------------------------------------------------------------
# Query
# ---------------------------------------------------------------------------


def _row_estimates(sk: CSVec, idx: jax.Array) -> jax.Array:
    bk, sg = row_buckets_signs(sk.coeffs, idx, sk.cols, sk.signed)
    gathered = jnp.take_along_axis(sk.table, bk, axis=1)     # (rows, n)
    return gathered * sg


def query(sk: CSVec, idx: jax.Array) -> jax.Array:
    """Point estimates at idx: median of rows (signed) / min (unsigned)."""
    est = _row_estimates(sk, idx)
    if sk.signed:
        return jnp.median(est, axis=0)
    return jnp.min(est, axis=0)


def query_row(sk: CSVec, idx: jax.Array, row: int) -> jax.Array:
    """Single-row estimate (no median combine) — the r=1 baseline."""
    return _row_estimates(sk, idx)[row]


def query_all(sk: CSVec) -> jax.Array:
    """Estimates for all d coordinates."""
    return query(sk, jnp.arange(sk.d, dtype=jnp.int32))


def topk(sk: CSVec, k: int) -> Tuple[jax.Array, jax.Array]:
    """Heavy hitters: (indices, estimates) of the k largest |estimate|."""
    est = query_all(sk)
    _, ix = jax.lax.top_k(jnp.abs(est), k)
    return ix, est[ix]


def l2_estimate(sk: CSVec) -> jax.Array:
    """||vec||_2 estimate: median over rows of per-row table norms."""
    return jnp.sqrt(jnp.median(jnp.sum(sk.table ** 2, axis=1)))


# ---------------------------------------------------------------------------
# Algebra / accounting
# ---------------------------------------------------------------------------


def merge(a: CSVec, b: CSVec) -> CSVec:
    """Sketch of the sum of the two underlying vectors.  Requires identical
    hashes — enforced via the static (seed, rows, cols) identity, so the
    check also works on traced tables under jit."""
    if (a.d, a.signed, a.seed) != (b.d, b.signed, b.seed) \
            or a.table.shape != b.table.shape:
        raise ValueError("CSVec mismatch: incompatible containers "
                         f"(d/signed/seed/shape {a.d}/{a.signed}/{a.seed}/"
                         f"{a.table.shape} vs {b.d}/{b.signed}/{b.seed}/"
                         f"{b.table.shape})")
    return dataclasses.replace(a, table=a.table + b.table)


def scale(sk: CSVec, alpha) -> CSVec:
    return dataclasses.replace(sk, table=sk.table * alpha)


def decay(sk: CSVec, factor: float = 0.5) -> CSVec:
    """Age the sketch: multiply all cells by ``factor`` (TinyLFU-style
    periodic reset).  For a count-min table this halves every estimated
    frequency while preserving the one-sided overestimate (min of scaled
    rows == scaled min), so admission thresholds keep their meaning and
    stale heavy hitters fade instead of occupying buckets forever.
    Unsigned (count-min) tables floor to keep integer-count semantics —
    a coordinate seen once and aged repeatedly decays to exactly zero
    rather than lingering as dust."""
    t = sk.table * jnp.float32(factor)
    if not sk.signed:
        t = jnp.floor(t)
    return dataclasses.replace(sk, table=t)


def state_bytes(sk: CSVec) -> int:
    """Persistent bytes: table + coefficients (hash tables are never
    materialized as state)."""
    return sk.table.size * sk.table.dtype.itemsize \
        + sk.coeffs.size * sk.coeffs.dtype.itemsize
